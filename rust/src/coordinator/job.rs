//! Batch tuning-job specifications.
//!
//! A [`TuningJob`] names everything that determines a tuning result —
//! model kind, verification engine, input size, platform configuration,
//! transition granularity, search method — plus the sharding degree (an
//! execution knob that does *not* affect the result and is therefore
//! excluded from the cache key). Jobs are parsed from a plain-text spec
//! file, one job per line:
//!
//! ```text
//! # four jobs; key=value pairs in any order after the model kind
//! job minimum size=64 np=4 gmt=3 method=exhaustive shards=4
//! job minimum size=128 np=4 gmt=3 method=swarm name=big-sweep
//! job abstract size=32 gmt=10 gran=phase
//! # the paper's own artifact: a Promela model, batch-tuned
//! job minimum size=16 engine=promela
//! ```
//!
//! `engine=promela` runs the job through the Promela front end
//! ([`crate::promela`]) instead of the native transition systems: the
//! model is the template `crate::promela::templates` generates for
//! (model, size, platform) — or, with `src=path/to/model.pml`, an
//! external source file. Promela jobs are cached under a **content hash
//! of the Promela source** (see [`TuningJob::cache_desc`]), so editing a
//! model can never serve a stale cached optimum.

use super::shard::TuningShard;
use crate::model::TransitionSystem;
use crate::platform::abstract_model::AbsState;
use crate::platform::min_model::MinState;
use crate::platform::{
    enumerate_tunings, AbstractModel, DataInit, Granularity, MinModel, PlatformConfig, Tuning,
};
use crate::promela::{
    source_hash, templates, vm::tuning_committed_at_init, PromelaSystem, PromelaVm, PState,
};
use crate::tuner::{Method, SearchMode};
use crate::util::error::{bail, ensure, Context, Result};

/// Which of the paper's models a job tunes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Abstract,
    Minimum,
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ModelKind::Abstract => "abstract",
            ModelKind::Minimum => "minimum",
        })
    }
}

impl std::str::FromStr for ModelKind {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "abstract" => Ok(ModelKind::Abstract),
            "minimum" => Ok(ModelKind::Minimum),
            other => bail!("unknown model kind `{}` (abstract | minimum)", other),
        }
    }
}

/// Which verification engine executes a job's model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum JobEngine {
    /// the optimized native transition systems (`crate::platform`)
    #[default]
    Native,
    /// the Promela front end (`crate::promela`) with full process
    /// interleaving — the paper's actual artifact, orders of magnitude
    /// more states than the native engines for the same model
    Promela,
}

impl std::fmt::Display for JobEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            JobEngine::Native => "native",
            JobEngine::Promela => "promela",
        })
    }
}

impl std::str::FromStr for JobEngine {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(JobEngine::Native),
            "promela" => Ok(JobEngine::Promela),
            other => bail!("unknown engine `{}` (native | promela)", other),
        }
    }
}

/// One batch tuning job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TuningJob {
    pub name: String,
    pub model: ModelKind,
    /// verification engine; `Promela` runs the generated template (or
    /// [`source`](Self::source)) through the front end
    pub engine: JobEngine,
    /// explicit Promela source text (`src=` spec key). `None` with
    /// `engine=promela` means "generate the [`model`](Self::model)
    /// template for (size, platform)". Ignored by the native engine.
    /// External sources must select (WG, TS) within the lattice `size`
    /// enumerates — sharding partitions *that* lattice, and a tuning
    /// outside it would be pruned from every shard.
    pub source: Option<String>,
    pub size: u32,
    pub plat: PlatformConfig,
    pub granularity: Granularity,
    pub method: Method,
    /// how the lattice is searched (`search=` spec key). An *execution*
    /// knob like [`shards`](Self::shards): surrogate and exhaustive mode
    /// return the identical optimum (see [`crate::tuner::surrogate`]),
    /// so the mode is excluded from the cache key and both modes share
    /// cache entries
    pub search: SearchMode,
    /// parameter-space shards this job is split into; 0 = "use the batch
    /// runner's default" (see `main.rs batch --shards`)
    pub shards: u32,
}

impl TuningJob {
    /// A job with the paper's defaults for `model` (Table-1 platform for
    /// the abstract model, the GMT=3 Table-3 calibration for Minimum).
    pub fn new(model: ModelKind, size: u32) -> Self {
        let plat = match model {
            ModelKind::Abstract => PlatformConfig::default(),
            ModelKind::Minimum => PlatformConfig { gmt: 3, ..PlatformConfig::default() },
        };
        Self {
            name: format!("{}-{}", model, size),
            model,
            engine: JobEngine::Native,
            source: None,
            size,
            plat,
            granularity: Granularity::Phase,
            method: Method::Exhaustive,
            search: SearchMode::Exhaustive,
            shards: 1,
        }
    }

    /// Surrogate search rides on exhaustive verification (its point
    /// oracle and certificate are exact `Cex` queries); the probabilistic
    /// swarm has no exactness to certify, so the combination is rejected
    /// up front instead of silently degrading.
    pub fn validate_modes(&self) -> Result<()> {
        ensure!(
            !(self.method == Method::Swarm && self.search == SearchMode::Surrogate),
            "job `{}`: surrogate search requires method=exhaustive (the swarm is probabilistic)",
            self.name
        );
        Ok(())
    }

    /// The job's size-independent *observation family*: what groups the
    /// surrogate-training observations this job produces with those of
    /// its siblings at other input sizes (cross-size neighbor
    /// warm-start). Native and template-Promela jobs share the
    /// structural (model, platform, granularity) family — the templates
    /// are pinned to the native models' times by the equivalence suite —
    /// while external sources get a content-hash family of their own
    /// (sizes of an edited model must never mix).
    pub fn obs_family(&self) -> String {
        if self.engine == JobEngine::Promela {
            if let Some(src) = &self.source {
                return format!("pml={:016x}", source_hash(src));
            }
        }
        format!(
            "model={} nd={} nu={} np={} gmt={} gran={}",
            self.model,
            self.plat.nd,
            self.plat.nu,
            self.plat.np,
            self.plat.gmt,
            match self.granularity {
                Granularity::Tick => "tick",
                Granularity::Phase => "phase",
            },
        )
    }

    /// The Promela source this job verifies (engine=promela only): the
    /// explicit [`source`](Self::source) when given, else the model-kind
    /// template for (size, platform). Callers must have validated the job
    /// ([`build`](Self::build) does) — the template generators assert on
    /// invalid sizes.
    pub(super) fn promela_source_text(&self) -> String {
        match &self.source {
            Some(src) => src.clone(),
            None => match self.model {
                ModelKind::Abstract => templates::abstract_pml(self.size, &self.plat),
                ModelKind::Minimum => {
                    templates::minimum_pml(self.size, self.plat.np, self.plat.gmt)
                }
            },
        }
    }

    /// Canonical cache description: everything that determines the result
    /// and nothing that does not (worker/shard counts are excluded, so a
    /// sharded run and a single-shot run share cache entries).
    ///
    /// Checker store kind and state/memory budgets are deliberately *not*
    /// part of the key for `Method::Exhaustive`: a bisection that
    /// completes is exact regardless of them — any lossy or truncated
    /// `Cex(T)` query fails `CheckReport::verdict` and errors out instead
    /// of returning, so no approximate exhaustive result can ever reach
    /// the cache. Swarm results *are* configuration-dependent; use
    /// [`cache_desc_with`](Self::cache_desc_with) to key those.
    ///
    /// Promela jobs key on a **content hash of the Promela source**
    /// (template-generated or explicit) instead of the structural fields:
    /// the source bytes fully determine the model (the templates embed
    /// size and platform, and the front end ignores `granularity`), so the
    /// hash subsumes them — placeholder fields alongside `src=` cannot
    /// fragment the key, a template job and an external file with
    /// byte-identical content share entries, and any edit to a model —
    /// even a comment — changes the key, so an edited model can never be
    /// served a stale entry. The native engines are keyed structurally and
    /// stay byte-compatible with pre-existing cache files.
    pub fn cache_desc(&self) -> String {
        let method = match self.method {
            Method::Exhaustive => "exhaustive",
            Method::Swarm => "swarm",
        };
        if self.engine == JobEngine::Promela {
            return format!(
                "engine=promela pml={:016x} method={} prop=over_time",
                source_hash(&self.promela_source_text()),
                method,
            );
        }
        format!(
            "model={} size={} nd={} nu={} np={} gmt={} gran={} method={} prop=over_time",
            self.model,
            self.size,
            self.plat.nd,
            self.plat.nu,
            self.plat.np,
            self.plat.gmt,
            match self.granularity {
                Granularity::Tick => "tick",
                Granularity::Phase => "phase",
            },
            method,
        )
    }

    /// [`cache_desc`](Self::cache_desc), plus the swarm configuration for
    /// `Method::Swarm` jobs. The swarm is probabilistic: its best-found
    /// optimum depends on worker count, seed, per-worker store size,
    /// depth bound and time budget, so those join the key — a swarm hit
    /// is only exact w.r.t. the configuration that produced it.
    /// Exhaustive jobs ignore `swarm` entirely and keep the plain key.
    pub fn cache_desc_with(&self, swarm: &crate::swarm::SwarmConfig) -> String {
        match self.method {
            Method::Exhaustive => self.cache_desc(),
            Method::Swarm => format!(
                "{} swarm=w{}:s{:#x}:b{}:h{}:d{}:t{}ms:e{}",
                self.cache_desc(),
                swarm.workers,
                swarm.seed,
                swarm.log2_bits,
                swarm.hashes,
                swarm.max_depth,
                swarm.time_budget.as_millis(),
                swarm.max_errors_per_worker,
            ),
        }
    }

    /// Content address of the job under [`crate::util::hash`].
    pub fn key(&self) -> u64 {
        crate::util::hash::hash_bytes(self.cache_desc().as_bytes())
    }

    /// Construct the job's transition system.
    pub fn build(&self) -> Result<JobModel> {
        match self.engine {
            JobEngine::Promela => {
                if self.source.is_none() {
                    // validate before template generation (the generators
                    // assert instead of erroring on bad sizes/platforms)
                    enumerate_tunings(self.size)?;
                    self.plat.validate()?;
                }
                let sys = PromelaSystem::from_source(&self.promela_source_text())?;
                // a source that never assigns WG/TS has a degenerate
                // lattice: every configuration verifies the same model,
                // and the batch would burn its budget re-proving one point
                crate::promela::analysis::require_tunable(&sys.prog)?;
                Ok(JobModel::Pml(sys))
            }
            JobEngine::Native => match self.model {
                ModelKind::Abstract => Ok(JobModel::Abs(AbstractModel::new(
                    self.size,
                    self.plat,
                    self.granularity,
                )?)),
                ModelKind::Minimum => Ok(JobModel::Min(MinModel::new(
                    self.size,
                    self.plat.np,
                    self.plat.gmt,
                    DataInit::Descending,
                    self.granularity,
                )?)),
            },
        }
    }

    /// Build the job's execution model for one (WG, TS) sub-lattice —
    /// the form phase 2 ([`super::run_shard_task`]) actually runs.
    ///
    /// Native models return as-is (the caller wraps them in the generic
    /// [`super::ShardModel`] re-filter; their closed-form successor
    /// generation is too cheap for specialization to pay). Promela jobs
    /// compile a **shard-specialized bytecode VM**: the bounds travel
    /// through batch planning and worker-mode manifests as four plain
    /// integers (`TaskSpec.plan.shard`) and are baked into the compiled
    /// program here, on whichever process executes the task — no
    /// serialized code, and every executor derives the identical
    /// specialized program. Sources whose initial image already commits
    /// a tuning violate the specialization contract and fall back to the
    /// unspecialized VM behind the generic wrapper.
    pub fn build_sharded(&self, shard: &TuningShard) -> Result<ShardedExec> {
        Ok(match self.build()? {
            JobModel::Abs(m) => ShardedExec::Abs(m),
            JobModel::Min(m) => ShardedExec::Min(m),
            JobModel::Pml(m) => {
                let prog = m.prog;
                if tuning_committed_at_init(&prog) {
                    ShardedExec::PmlWrapped(PromelaVm::new(prog)?)
                } else {
                    ShardedExec::PmlSpecialized(PromelaVm::specialized(
                        prog,
                        Some(shard.promela_bounds()),
                    )?)
                }
            }
        })
    }

    /// Ground-truth optimal model time (for tests and report checks).
    /// Valid for Promela *template* jobs too — the templates are pinned to
    /// the native models' `predicted_time` by the equivalence tests — but
    /// not for external `src=` sources, which have no closed form.
    pub fn optimum_time(&self) -> Result<u64> {
        ensure!(
            self.source.is_none(),
            "an external Promela source has no closed-form optimum"
        );
        Ok(match self.model {
            ModelKind::Abstract => {
                AbstractModel::new(self.size, self.plat, self.granularity)?.optimum().0
            }
            ModelKind::Minimum => MinModel::new(
                self.size,
                self.plat.np,
                self.plat.gmt,
                DataInit::Descending,
                self.granularity,
            )?
            .optimum()
            .0,
        })
    }

    /// Per-tuning state-space cost estimates over the job's (WG, TS)
    /// lattice — the input to shard weighting ([`super::shard::plan_shards`])
    /// and adaptive shard counts. The estimate is the native model's
    /// closed-form `predicted_time`: the number of states the checker
    /// stores along one tuning branch is proportional to that branch's
    /// tick count in every engine (ticks for `Tick` granularity, phases
    /// for `Phase`, interleavings-per-tick for Promela — all monotone in
    /// it), and only the *relative* weights matter for budget splits.
    ///
    /// External Promela sources have no closed form; they are estimated
    /// by a cheap bounded **guided-simulation sweep**: one short walk per
    /// tuning with off-target (WG, TS) choices pruned at the selection
    /// point, weighting the tuning by its observed terminal `time` (an
    /// *achievable* time, so the derived `ShardPlan::t_ini` is a sound
    /// `Cex` bound) with the walked step count as fallback. Skewed models
    /// therefore get proportional shard budgets instead of the uniform
    /// weights they used to.
    pub fn tuning_costs(&self) -> Result<Vec<(Tuning, u64)>> {
        let tunings = enumerate_tunings(self.size)?;
        if let Some(src) = &self.source {
            let sys = PromelaSystem::from_source(src)?;
            // 20k steps bounds plan latency on cyclic models (a walk that
            // never terminates costs runs x 20k interpreter steps, not
            // unbounded); one interleaving of the bundled templates runs
            // a few thousand steps, far under the bound
            return Ok(tunings
                .into_iter()
                .map(|t| (t, guided_sim_cost(&sys, t, 2, 20_000)))
                .collect());
        }
        Ok(match self.model {
            ModelKind::Abstract => {
                let m = AbstractModel::new(self.size, self.plat, self.granularity)?;
                tunings.into_iter().map(|t| (t, m.predicted_time(t).max(1))).collect()
            }
            ModelKind::Minimum => {
                let m = MinModel::new(
                    self.size,
                    self.plat.np,
                    self.plat.gmt,
                    DataInit::Descending,
                    self.granularity,
                )?;
                tunings.into_iter().map(|t| (t, m.predicted_time(t).max(1))).collect()
            }
        })
    }

    /// Parse a spec file (see the module docs for the format). Jobs that
    /// do not set `shards=` get `shards = 0`, meaning "runner default".
    pub fn parse_spec(text: &str) -> Result<Vec<TuningJob>> {
        let mut jobs = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut tokens = line.split_whitespace();
            let head = tokens.next().expect("non-empty line has a token");
            if head != "job" {
                bail!("spec line {}: expected `job <model> [k=v...]`, got `{}`", lineno + 1, line);
            }
            let kind: ModelKind = tokens
                .next()
                .with_context(|| format!("spec line {}: missing model kind", lineno + 1))?
                .parse()
                .with_context(|| format!("spec line {}", lineno + 1))?;
            let mut job = TuningJob::new(kind, 64);
            job.shards = 0;
            let mut named = false;
            for tok in tokens {
                let (key, value) = tok
                    .split_once('=')
                    .with_context(|| format!("spec line {}: `{}` is not key=value", lineno + 1, tok))?;
                let int = |what: &str| -> Result<u32> {
                    value
                        .parse::<u32>()
                        .with_context(|| format!("spec line {}: bad {} `{}`", lineno + 1, what, value))
                };
                match key {
                    "name" => {
                        job.name = value.to_string();
                        named = true;
                    }
                    "size" => job.size = int("size")?,
                    "np" => job.plat.np = int("np")?,
                    "nd" => job.plat.nd = int("nd")?,
                    "nu" => job.plat.nu = int("nu")?,
                    "gmt" => job.plat.gmt = int("gmt")?,
                    "shards" => job.shards = int("shards")?,
                    "engine" => {
                        job.engine = value
                            .parse()
                            .with_context(|| format!("spec line {}", lineno + 1))?
                    }
                    "src" => {
                        let text = std::fs::read_to_string(value).with_context(|| {
                            format!("spec line {}: reading Promela source `{}`", lineno + 1, value)
                        })?;
                        job.engine = JobEngine::Promela; // src= implies the engine
                        job.source = Some(text);
                    }
                    "gran" | "granularity" => {
                        job.granularity = match value {
                            "tick" => Granularity::Tick,
                            "phase" => Granularity::Phase,
                            g => bail!("spec line {}: unknown granularity `{}`", lineno + 1, g),
                        }
                    }
                    "method" => {
                        job.method = value
                            .parse()
                            .with_context(|| format!("spec line {}", lineno + 1))?
                    }
                    "search" => {
                        job.search = value
                            .parse()
                            .with_context(|| format!("spec line {}", lineno + 1))?
                    }
                    other => bail!("spec line {}: unknown key `{}`", lineno + 1, other),
                }
            }
            if !named {
                job.name = format!("{}-{}", job.model, job.size);
            }
            job.validate_modes().with_context(|| format!("spec line {}", lineno + 1))?;
            // fail fast on invalid sizes/platforms instead of mid-batch
            job.build().with_context(|| format!("spec line {}: invalid job", lineno + 1))?;
            jobs.push(job);
        }
        Ok(jobs)
    }
}

/// True when `s` has not committed to a (WG, TS) incompatible with `t`:
/// each observable is either unset (absent or non-positive — Promela
/// globals read 0 before the select) or equal to the target. `slots` are
/// the pre-resolved dense slot ids for (WG, TS) — this runs per successor
/// on the walk's hot path, and `PromelaSystem::eval_var` is a string-hash
/// lookup (same reasoning as `ShardModel::new`).
fn compatible(sys: &PromelaSystem, s: &PState, t: Tuning, slots: Option<(u32, u32)>) -> bool {
    let ok = |v: Option<i64>, want: u32| !matches!(v, Some(x) if x > 0 && x != want as i64);
    match slots {
        Some((w, ts)) => {
            let ids = [w, ts];
            let mut out = [0i64; 2];
            let missing = sys.eval_slots(s, &ids, &mut out);
            ok((missing & 0b01 == 0).then_some(out[0]), t.wg)
                && ok((missing & 0b10 == 0).then_some(out[1]), t.ts)
        }
        None => ok(sys.eval_var(s, "WG"), t.wg) && ok(sys.eval_var(s, "TS"), t.ts),
    }
}

/// Bounded guided simulation of an external Promela source pinned to `t`:
/// a random walk that, at every nondeterministic choice, follows only
/// successors [`compatible`] with the target tuning — unlike a walk on a
/// sharded model it can never dead-end in an off-target branch, because
/// the target branch itself always remains. The cost is the maximum over
/// `runs` walks of the observed terminal `time` (positive terminal times
/// are achievable for `t`, which is exactly what `ShardPlan::t_ini`
/// needs) with the walked step count as fallback for models that do not
/// expose `time`, hit `max_steps`, or cannot reach `t` at all. Seeds are
/// fixed per (tuning, run), so estimates — and therefore shard plans —
/// are reproducible across processes.
fn guided_sim_cost(sys: &PromelaSystem, t: Tuning, runs: u64, max_steps: u64) -> u64 {
    use crate::util::rng::Xoshiro256;
    let slots = match (sys.resolve_slot("WG"), sys.resolve_slot("TS")) {
        (Some(w), Some(ts)) => Some((w, ts)),
        _ => None,
    };
    let mut best = 0u64;
    let mut buf: Vec<PState> = Vec::new();
    for run in 0..runs {
        let seed =
            0x5EED_0000_0000_0000u64 ^ ((t.wg as u64) << 32) ^ ((t.ts as u64) << 8) ^ run;
        let mut rng = Xoshiro256::new(seed);
        let inits = sys.initial_states();
        if inits.is_empty() {
            return 1;
        }
        let mut state = inits[rng.below(inits.len() as u64) as usize].clone();
        let mut steps = 0u64;
        let cost = loop {
            sys.successors(&state, &mut buf);
            if buf.is_empty() {
                // terminal: the observed time was reached by a real run
                break match sys.eval_var(&state, "time") {
                    Some(time) if time > 0 => time as u64,
                    _ => steps,
                };
            }
            if steps >= max_steps {
                break steps;
            }
            buf.retain(|s| compatible(sys, s, t, slots));
            if buf.is_empty() {
                break steps; // `t` unreachable along any continuation
            }
            state = buf[rng.below(buf.len() as u64) as usize].clone();
            steps += 1;
        };
        best = best.max(cost);
    }
    best.max(1)
}

/// A constructed model for a job. The [`TransitionSystem`] impl
/// dispatches uniformly over the kinds for cold paths (inspection,
/// tests); hot paths should match on the variant and run the concrete
/// model directly — the uniform interface costs a temporary successor
/// buffer per expanded state, which the checker's reused-`out` contract
/// otherwise avoids (see `run_batch`'s phase 2). `Pml` carries the
/// stage-one program through the front end; shard execution lowers it to
/// the bytecode VM via [`TuningJob::build_sharded`].
pub enum JobModel {
    Abs(AbstractModel),
    Min(MinModel),
    Pml(PromelaSystem),
}

/// A job model prepared for one shard (see [`TuningJob::build_sharded`]).
pub enum ShardedExec {
    Abs(AbstractModel),
    Min(MinModel),
    /// unspecialized VM — run behind the generic [`super::ShardModel`]
    /// re-filter (initial-image-committed fallback)
    PmlWrapped(PromelaVm),
    /// shard bounds compiled into the program — run directly
    PmlSpecialized(PromelaVm),
}

/// State of a [`JobModel`] — tags the underlying model's state.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum JobState {
    Abs(AbsState),
    Min(MinState),
    Pml(PState),
}

impl TransitionSystem for JobModel {
    type State = JobState;

    fn initial_states(&self) -> Vec<JobState> {
        match self {
            JobModel::Abs(m) => m.initial_states().into_iter().map(JobState::Abs).collect(),
            JobModel::Min(m) => m.initial_states().into_iter().map(JobState::Min).collect(),
            JobModel::Pml(m) => m.initial_states().into_iter().map(JobState::Pml).collect(),
        }
    }

    fn successors(&self, s: &JobState, out: &mut Vec<JobState>) {
        out.clear();
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => {
                let mut buf = Vec::new();
                m.successors(s, &mut buf);
                out.extend(buf.into_iter().map(JobState::Abs));
            }
            (JobModel::Min(m), JobState::Min(s)) => {
                let mut buf = Vec::new();
                m.successors(s, &mut buf);
                out.extend(buf.into_iter().map(JobState::Min));
            }
            (JobModel::Pml(m), JobState::Pml(s)) => {
                let mut buf = Vec::new();
                m.successors(s, &mut buf);
                out.extend(buf.into_iter().map(JobState::Pml));
            }
            _ => unreachable!("state kind does not match model kind"),
        }
    }

    fn encode(&self, s: &JobState, out: &mut Vec<u8>) {
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => m.encode(s, out),
            (JobModel::Min(m), JobState::Min(s)) => m.encode(s, out),
            (JobModel::Pml(m), JobState::Pml(s)) => m.encode(s, out),
            _ => unreachable!("state kind does not match model kind"),
        }
    }

    fn eval_var(&self, s: &JobState, name: &str) -> Option<i64> {
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => m.eval_var(s, name),
            (JobModel::Min(m), JobState::Min(s)) => m.eval_var(s, name),
            (JobModel::Pml(m), JobState::Pml(s)) => m.eval_var(s, name),
            _ => unreachable!("state kind does not match model kind"),
        }
    }

    fn describe(&self, s: &JobState) -> String {
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => m.describe(s),
            (JobModel::Min(m), JobState::Min(s)) => m.describe(s),
            (JobModel::Pml(m), JobState::Pml(s)) => m.describe(s),
            _ => unreachable!("state kind does not match model kind"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_jobs_with_defaults_and_overrides() {
        let jobs = TuningJob::parse_spec(
            "# comment\n\
             \n\
             job minimum size=64 np=4 gmt=3 shards=4\n\
             job abstract size=32 method=swarm name=sw32\n",
        )
        .unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].name, "minimum-64");
        assert_eq!(jobs[0].shards, 4);
        assert_eq!(jobs[0].plat.gmt, 3);
        assert_eq!(jobs[1].name, "sw32");
        assert_eq!(jobs[1].method, Method::Swarm);
        assert_eq!(jobs[1].shards, 0, "unset shards defer to the runner default");
        assert_eq!(jobs[1].plat.gmt, 10, "abstract defaults to the Table-1 GMT");
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(TuningJob::parse_spec("tune minimum\n").is_err());
        assert!(TuningJob::parse_spec("job warp size=64\n").is_err());
        assert!(TuningJob::parse_spec("job minimum size\n").is_err());
        assert!(TuningJob::parse_spec("job minimum size=twelve\n").is_err());
        assert!(TuningJob::parse_spec("job minimum color=red\n").is_err());
        assert!(TuningJob::parse_spec("job minimum size=12\n").is_err(), "non-pow2 size");
    }

    #[test]
    fn cache_desc_excludes_sharding_and_name() {
        let mut a = TuningJob::new(ModelKind::Minimum, 64);
        let mut b = a.clone();
        b.shards = 8;
        b.name = "other".into();
        assert_eq!(a.cache_desc(), b.cache_desc());
        assert_eq!(a.key(), b.key());
        a.method = Method::Swarm;
        assert_ne!(a.cache_desc(), b.cache_desc());
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn cache_desc_excludes_search_mode() {
        // surrogate results are differential-equal to exhaustive ones, so
        // the mode is an execution knob: both modes share cache entries
        let a = TuningJob::new(ModelKind::Minimum, 64);
        let mut b = a.clone();
        b.search = SearchMode::Surrogate;
        assert_eq!(a.cache_desc(), b.cache_desc());
        assert_eq!(a.key(), b.key());
    }

    #[test]
    fn spec_parses_search_mode_and_rejects_surrogate_swarm() {
        let jobs = TuningJob::parse_spec(
            "job minimum size=64 search=surrogate\n\
             job minimum size=64 search=exhaustive\n\
             job minimum size=64\n",
        )
        .unwrap();
        assert_eq!(jobs[0].search, SearchMode::Surrogate);
        assert_eq!(jobs[1].search, SearchMode::Exhaustive);
        assert_eq!(jobs[2].search, SearchMode::Exhaustive, "default is exhaustive");
        assert!(TuningJob::parse_spec("job minimum size=64 search=bayesian\n").is_err());
        assert!(
            TuningJob::parse_spec("job minimum size=64 method=swarm search=surrogate\n").is_err(),
            "surrogate rides on exhaustive verification only"
        );
    }

    #[test]
    fn obs_family_is_size_independent_and_source_addressed() {
        let a = TuningJob::new(ModelKind::Minimum, 64);
        let mut b = TuningJob::new(ModelKind::Minimum, 128);
        assert_eq!(a.obs_family(), b.obs_family(), "sizes share a family");
        b.plat.gmt = 7;
        assert_ne!(a.obs_family(), b.obs_family(), "platform changes split the family");
        // a template-promela job shares its native sibling's family (the
        // templates are pinned to the native times)...
        let mut tpl = TuningJob::new(ModelKind::Minimum, 64);
        tpl.engine = JobEngine::Promela;
        assert_eq!(tpl.obs_family(), a.obs_family());
        // ...but an external source is content-addressed on its own
        let mut ext = tpl.clone();
        ext.source = Some("int WG; int TS; bool FIN; active proctype main() { FIN = true }".into());
        assert!(ext.obs_family().starts_with("pml="));
        assert_ne!(ext.obs_family(), a.obs_family());
    }

    #[test]
    fn swarm_cache_key_tracks_swarm_config_but_exhaustive_does_not() {
        use crate::swarm::SwarmConfig;
        let mut job = TuningJob::new(ModelKind::Minimum, 64);
        let a = SwarmConfig::default();
        let b = SwarmConfig { seed: 0xBEEF, ..SwarmConfig::default() };
        // exhaustive results are exact: the swarm config is irrelevant
        assert_eq!(job.cache_desc_with(&a), job.cache_desc());
        assert_eq!(job.cache_desc_with(&a), job.cache_desc_with(&b));
        // swarm results are configuration-dependent: the config joins the key
        job.method = Method::Swarm;
        assert_ne!(job.cache_desc_with(&a), job.cache_desc());
        assert_ne!(job.cache_desc_with(&a), job.cache_desc_with(&b));
    }

    #[test]
    fn job_model_dispatches_both_kinds() {
        for kind in [ModelKind::Abstract, ModelKind::Minimum] {
            let m = TuningJob::new(kind, 16).build().unwrap();
            let inits = m.initial_states();
            assert_eq!(inits.len(), 1);
            let mut succs = Vec::new();
            m.successors(&inits[0], &mut succs);
            assert!(!succs.is_empty());
            // after the tuning choice, WG/TS are observable
            assert!(m.eval_var(&succs[0], "WG").is_some());
            assert!(m.eval_var(&succs[0], "TS").is_some());
            let mut enc = Vec::new();
            m.encode(&succs[0], &mut enc);
            assert!(!enc.is_empty());
            assert!(!m.describe(&succs[0]).is_empty());
        }
    }

    #[test]
    fn optimum_time_matches_underlying_model() {
        let job = TuningJob::new(ModelKind::Minimum, 64);
        let m = MinModel::paper(64, 4).unwrap();
        assert_eq!(job.optimum_time().unwrap(), m.optimum().0);
    }

    #[test]
    fn spec_parses_promela_engine_jobs() {
        let jobs = TuningJob::parse_spec(
            "job minimum size=16 engine=promela shards=2\n\
             job abstract size=8 engine=promela np=2 gmt=2\n\
             job minimum size=16\n",
        )
        .unwrap();
        assert_eq!(jobs[0].engine, JobEngine::Promela);
        assert_eq!(jobs[1].engine, JobEngine::Promela);
        assert_eq!(jobs[2].engine, JobEngine::Native);
        assert!(matches!(jobs[0].build().unwrap(), JobModel::Pml(_)));
        assert!(matches!(jobs[2].build().unwrap(), JobModel::Min(_)));
        // bad engine value and invalid promela sizes are spec errors, not panics
        assert!(TuningJob::parse_spec("job minimum engine=spin\n").is_err());
        assert!(TuningJob::parse_spec("job minimum size=12 engine=promela\n").is_err());
    }

    #[test]
    fn promela_cache_key_is_content_addressed() {
        let mut a = TuningJob::new(ModelKind::Minimum, 16);
        assert!(!a.cache_desc().contains("pml="), "native keys stay byte-compatible");
        a.engine = JobEngine::Promela;
        let template_desc = a.cache_desc();
        assert!(template_desc.contains("engine=promela pml="));
        // an explicit source with identical bytes shares the key...
        let mut b = a.clone();
        b.source = Some(crate::promela::templates::minimum_pml(16, 4, 3));
        assert_eq!(b.cache_desc(), template_desc);
        // ...and any edit — even a comment — changes it
        let mut c = a.clone();
        c.source = Some(format!("// edited\n{}", crate::promela::templates::minimum_pml(16, 4, 3)));
        assert_ne!(c.cache_desc(), template_desc);
        // sharding degree still never touches the key
        let mut d = a.clone();
        d.shards = 7;
        assert_eq!(d.cache_desc(), template_desc);
    }

    #[test]
    fn build_sharded_specializes_promela_and_passes_natives_through() {
        let shard = TuningShard { wg_min: 2, wg_max: 2, ts_min: 0, ts_max: u32::MAX };
        let native = TuningJob::new(ModelKind::Minimum, 16);
        assert!(matches!(native.build_sharded(&shard).unwrap(), ShardedExec::Min(_)));
        let mut pml = native.clone();
        pml.engine = JobEngine::Promela;
        match pml.build_sharded(&shard).unwrap() {
            ShardedExec::PmlSpecialized(vm) => assert!(vm.is_specialized()),
            _ => panic!("promela job must compile a shard-specialized VM"),
        }
        // a source whose initial image already commits the tuning violates
        // the specialization contract and falls back to the wrapped VM
        let mut preset = pml.clone();
        preset.source = Some(
            "int WG = 2; int TS = 2; bool FIN; active proctype main() { FIN = true }".into(),
        );
        assert!(matches!(preset.build_sharded(&shard).unwrap(), ShardedExec::PmlWrapped(_)));
    }

    #[test]
    fn tuning_costs_track_predicted_time() {
        let job = TuningJob::new(ModelKind::Minimum, 64);
        let m = MinModel::paper(64, 4).unwrap();
        let costs = job.tuning_costs().unwrap();
        assert_eq!(costs.len(), m.tunings().len());
        for &(t, c) in &costs {
            assert_eq!(c, m.predicted_time(t).max(1));
        }
        // external sources: estimated by the guided-simulation sweep. A
        // model that never reads (WG, TS) walks identically for every
        // tuning, so its weights stay uniform (and positive)
        let mut ext = job.clone();
        ext.engine = JobEngine::Promela;
        ext.source = Some("int x; active proctype main() { x = 1 }".into());
        let ext_costs = ext.tuning_costs().unwrap();
        assert!(ext_costs.iter().all(|&(_, c)| c >= 1));
        assert!(
            ext_costs.windows(2).all(|w| w[0].1 == w[1].1),
            "tuning-independent model must weigh uniform: {:?}",
            ext_costs
        );
        assert!(ext.optimum_time().is_err(), "no closed form for external sources");
    }

    #[test]
    fn external_source_costs_are_simulation_weighted_and_deterministic() {
        // a *skewed* external model — the Minimum template, whose runtime
        // depends strongly on (WG, TS) — must get non-uniform weights,
        // and the observed terminal times must be achievable (they equal
        // real walk outcomes, so each weight is a sound Cex bound)
        let mut job = TuningJob::new(ModelKind::Minimum, 16);
        job.engine = JobEngine::Promela;
        job.source = Some(crate::promela::templates::minimum_pml(16, 4, 3));
        let costs = job.tuning_costs().unwrap();
        assert!(costs.len() > 1);
        assert!(costs.iter().all(|&(_, c)| c >= 1));
        assert!(
            costs.windows(2).any(|w| w[0].1 != w[1].1),
            "the Minimum model is cost-skewed; the sweep must see it: {:?}",
            costs
        );
        // fixed seeds: the estimate (and every shard plan derived from
        // it) is reproducible across processes — worker mode depends on
        // the planner and a single-process run agreeing
        assert_eq!(costs, job.tuning_costs().unwrap());
    }
}
