//! Batch tuning-job specifications.
//!
//! A [`TuningJob`] names everything that determines a tuning result —
//! model kind, input size, platform configuration, transition
//! granularity, search method — plus the sharding degree (an execution
//! knob that does *not* affect the result and is therefore excluded from
//! the cache key). Jobs are parsed from a plain-text spec file, one job
//! per line:
//!
//! ```text
//! # three jobs; key=value pairs in any order after the model kind
//! job minimum size=64 np=4 gmt=3 method=exhaustive shards=4
//! job minimum size=128 np=4 gmt=3 method=swarm name=big-sweep
//! job abstract size=32 gmt=10 gran=phase
//! ```

use crate::model::TransitionSystem;
use crate::platform::abstract_model::AbsState;
use crate::platform::min_model::MinState;
use crate::platform::{AbstractModel, DataInit, Granularity, MinModel, PlatformConfig};
use crate::tuner::Method;
use crate::util::error::{bail, Context, Result};

/// Which of the paper's models a job tunes (native engines only; the
/// Promela front end stays on the single-shot `verify`/`tune` path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Abstract,
    Minimum,
}

impl std::fmt::Display for ModelKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ModelKind::Abstract => "abstract",
            ModelKind::Minimum => "minimum",
        })
    }
}

impl std::str::FromStr for ModelKind {
    type Err = crate::util::error::Error;

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "abstract" => Ok(ModelKind::Abstract),
            "minimum" => Ok(ModelKind::Minimum),
            other => bail!("unknown model kind `{}` (abstract | minimum)", other),
        }
    }
}

/// One batch tuning job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TuningJob {
    pub name: String,
    pub model: ModelKind,
    pub size: u32,
    pub plat: PlatformConfig,
    pub granularity: Granularity,
    pub method: Method,
    /// parameter-space shards this job is split into; 0 = "use the batch
    /// runner's default" (see `main.rs batch --shards`)
    pub shards: u32,
}

impl TuningJob {
    /// A job with the paper's defaults for `model` (Table-1 platform for
    /// the abstract model, the GMT=3 Table-3 calibration for Minimum).
    pub fn new(model: ModelKind, size: u32) -> Self {
        let plat = match model {
            ModelKind::Abstract => PlatformConfig::default(),
            ModelKind::Minimum => PlatformConfig { gmt: 3, ..PlatformConfig::default() },
        };
        Self {
            name: format!("{}-{}", model, size),
            model,
            size,
            plat,
            granularity: Granularity::Phase,
            method: Method::Exhaustive,
            shards: 1,
        }
    }

    /// Canonical cache description: everything that determines the result
    /// and nothing that does not (worker/shard counts are excluded, so a
    /// sharded run and a single-shot run share cache entries).
    ///
    /// Checker store kind and state/memory budgets are deliberately *not*
    /// part of the key for `Method::Exhaustive`: a bisection that
    /// completes is exact regardless of them — any lossy or truncated
    /// `Cex(T)` query fails `CheckReport::verdict` and errors out instead
    /// of returning, so no approximate exhaustive result can ever reach
    /// the cache. Swarm results *are* configuration-dependent; use
    /// [`cache_desc_with`](Self::cache_desc_with) to key those.
    pub fn cache_desc(&self) -> String {
        format!(
            "model={} size={} nd={} nu={} np={} gmt={} gran={} method={} prop=over_time",
            self.model,
            self.size,
            self.plat.nd,
            self.plat.nu,
            self.plat.np,
            self.plat.gmt,
            match self.granularity {
                Granularity::Tick => "tick",
                Granularity::Phase => "phase",
            },
            match self.method {
                Method::Exhaustive => "exhaustive",
                Method::Swarm => "swarm",
            },
        )
    }

    /// [`cache_desc`](Self::cache_desc), plus the swarm configuration for
    /// `Method::Swarm` jobs. The swarm is probabilistic: its best-found
    /// optimum depends on worker count, seed, per-worker store size,
    /// depth bound and time budget, so those join the key — a swarm hit
    /// is only exact w.r.t. the configuration that produced it.
    /// Exhaustive jobs ignore `swarm` entirely and keep the plain key.
    pub fn cache_desc_with(&self, swarm: &crate::swarm::SwarmConfig) -> String {
        match self.method {
            Method::Exhaustive => self.cache_desc(),
            Method::Swarm => format!(
                "{} swarm=w{}:s{:#x}:b{}:h{}:d{}:t{}ms:e{}",
                self.cache_desc(),
                swarm.workers,
                swarm.seed,
                swarm.log2_bits,
                swarm.hashes,
                swarm.max_depth,
                swarm.time_budget.as_millis(),
                swarm.max_errors_per_worker,
            ),
        }
    }

    /// Content address of the job under [`crate::util::hash`].
    pub fn key(&self) -> u64 {
        crate::util::hash::hash_bytes(self.cache_desc().as_bytes())
    }

    /// Construct the job's native transition system.
    pub fn build(&self) -> Result<JobModel> {
        match self.model {
            ModelKind::Abstract => Ok(JobModel::Abs(AbstractModel::new(
                self.size,
                self.plat,
                self.granularity,
            )?)),
            ModelKind::Minimum => Ok(JobModel::Min(MinModel::new(
                self.size,
                self.plat.np,
                self.plat.gmt,
                DataInit::Descending,
                self.granularity,
            )?)),
        }
    }

    /// Ground-truth optimal model time (for tests and report checks).
    pub fn optimum_time(&self) -> Result<u64> {
        Ok(match self.build()? {
            JobModel::Abs(m) => m.optimum().0,
            JobModel::Min(m) => m.optimum().0,
        })
    }

    /// Parse a spec file (see the module docs for the format). Jobs that
    /// do not set `shards=` get `shards = 0`, meaning "runner default".
    pub fn parse_spec(text: &str) -> Result<Vec<TuningJob>> {
        let mut jobs = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut tokens = line.split_whitespace();
            let head = tokens.next().expect("non-empty line has a token");
            if head != "job" {
                bail!("spec line {}: expected `job <model> [k=v...]`, got `{}`", lineno + 1, line);
            }
            let kind: ModelKind = tokens
                .next()
                .with_context(|| format!("spec line {}: missing model kind", lineno + 1))?
                .parse()
                .with_context(|| format!("spec line {}", lineno + 1))?;
            let mut job = TuningJob::new(kind, 64);
            job.shards = 0;
            let mut named = false;
            for tok in tokens {
                let (key, value) = tok
                    .split_once('=')
                    .with_context(|| format!("spec line {}: `{}` is not key=value", lineno + 1, tok))?;
                let int = |what: &str| -> Result<u32> {
                    value
                        .parse::<u32>()
                        .with_context(|| format!("spec line {}: bad {} `{}`", lineno + 1, what, value))
                };
                match key {
                    "name" => {
                        job.name = value.to_string();
                        named = true;
                    }
                    "size" => job.size = int("size")?,
                    "np" => job.plat.np = int("np")?,
                    "nd" => job.plat.nd = int("nd")?,
                    "nu" => job.plat.nu = int("nu")?,
                    "gmt" => job.plat.gmt = int("gmt")?,
                    "shards" => job.shards = int("shards")?,
                    "gran" | "granularity" => {
                        job.granularity = match value {
                            "tick" => Granularity::Tick,
                            "phase" => Granularity::Phase,
                            g => bail!("spec line {}: unknown granularity `{}`", lineno + 1, g),
                        }
                    }
                    "method" => {
                        job.method = value
                            .parse()
                            .with_context(|| format!("spec line {}", lineno + 1))?
                    }
                    other => bail!("spec line {}: unknown key `{}`", lineno + 1, other),
                }
            }
            if !named {
                job.name = format!("{}-{}", job.model, job.size);
            }
            // fail fast on invalid sizes/platforms instead of mid-batch
            job.build().with_context(|| format!("spec line {}: invalid job", lineno + 1))?;
            jobs.push(job);
        }
        Ok(jobs)
    }
}

/// A constructed native model for a job. The [`TransitionSystem`] impl
/// dispatches uniformly over both kinds for cold paths (inspection,
/// tests); hot paths should match on the variant and run the concrete
/// model directly — the uniform interface costs a temporary successor
/// buffer per expanded state, which the checker's reused-`out` contract
/// otherwise avoids (see `run_batch`'s phase 2).
pub enum JobModel {
    Abs(AbstractModel),
    Min(MinModel),
}

/// State of a [`JobModel`] — tags the underlying model's state.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobState {
    Abs(AbsState),
    Min(MinState),
}

impl TransitionSystem for JobModel {
    type State = JobState;

    fn initial_states(&self) -> Vec<JobState> {
        match self {
            JobModel::Abs(m) => m.initial_states().into_iter().map(JobState::Abs).collect(),
            JobModel::Min(m) => m.initial_states().into_iter().map(JobState::Min).collect(),
        }
    }

    fn successors(&self, s: &JobState, out: &mut Vec<JobState>) {
        out.clear();
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => {
                let mut buf = Vec::new();
                m.successors(s, &mut buf);
                out.extend(buf.into_iter().map(JobState::Abs));
            }
            (JobModel::Min(m), JobState::Min(s)) => {
                let mut buf = Vec::new();
                m.successors(s, &mut buf);
                out.extend(buf.into_iter().map(JobState::Min));
            }
            _ => unreachable!("state kind does not match model kind"),
        }
    }

    fn encode(&self, s: &JobState, out: &mut Vec<u8>) {
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => m.encode(s, out),
            (JobModel::Min(m), JobState::Min(s)) => m.encode(s, out),
            _ => unreachable!("state kind does not match model kind"),
        }
    }

    fn eval_var(&self, s: &JobState, name: &str) -> Option<i64> {
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => m.eval_var(s, name),
            (JobModel::Min(m), JobState::Min(s)) => m.eval_var(s, name),
            _ => unreachable!("state kind does not match model kind"),
        }
    }

    fn describe(&self, s: &JobState) -> String {
        match (self, s) {
            (JobModel::Abs(m), JobState::Abs(s)) => m.describe(s),
            (JobModel::Min(m), JobState::Min(s)) => m.describe(s),
            _ => unreachable!("state kind does not match model kind"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_jobs_with_defaults_and_overrides() {
        let jobs = TuningJob::parse_spec(
            "# comment\n\
             \n\
             job minimum size=64 np=4 gmt=3 shards=4\n\
             job abstract size=32 method=swarm name=sw32\n",
        )
        .unwrap();
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].name, "minimum-64");
        assert_eq!(jobs[0].shards, 4);
        assert_eq!(jobs[0].plat.gmt, 3);
        assert_eq!(jobs[1].name, "sw32");
        assert_eq!(jobs[1].method, Method::Swarm);
        assert_eq!(jobs[1].shards, 0, "unset shards defer to the runner default");
        assert_eq!(jobs[1].plat.gmt, 10, "abstract defaults to the Table-1 GMT");
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(TuningJob::parse_spec("tune minimum\n").is_err());
        assert!(TuningJob::parse_spec("job warp size=64\n").is_err());
        assert!(TuningJob::parse_spec("job minimum size\n").is_err());
        assert!(TuningJob::parse_spec("job minimum size=twelve\n").is_err());
        assert!(TuningJob::parse_spec("job minimum color=red\n").is_err());
        assert!(TuningJob::parse_spec("job minimum size=12\n").is_err(), "non-pow2 size");
    }

    #[test]
    fn cache_desc_excludes_sharding_and_name() {
        let mut a = TuningJob::new(ModelKind::Minimum, 64);
        let mut b = a.clone();
        b.shards = 8;
        b.name = "other".into();
        assert_eq!(a.cache_desc(), b.cache_desc());
        assert_eq!(a.key(), b.key());
        a.method = Method::Swarm;
        assert_ne!(a.cache_desc(), b.cache_desc());
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn swarm_cache_key_tracks_swarm_config_but_exhaustive_does_not() {
        use crate::swarm::SwarmConfig;
        let mut job = TuningJob::new(ModelKind::Minimum, 64);
        let a = SwarmConfig::default();
        let b = SwarmConfig { seed: 0xBEEF, ..SwarmConfig::default() };
        // exhaustive results are exact: the swarm config is irrelevant
        assert_eq!(job.cache_desc_with(&a), job.cache_desc());
        assert_eq!(job.cache_desc_with(&a), job.cache_desc_with(&b));
        // swarm results are configuration-dependent: the config joins the key
        job.method = Method::Swarm;
        assert_ne!(job.cache_desc_with(&a), job.cache_desc());
        assert_ne!(job.cache_desc_with(&a), job.cache_desc_with(&b));
    }

    #[test]
    fn job_model_dispatches_both_kinds() {
        for kind in [ModelKind::Abstract, ModelKind::Minimum] {
            let m = TuningJob::new(kind, 16).build().unwrap();
            let inits = m.initial_states();
            assert_eq!(inits.len(), 1);
            let mut succs = Vec::new();
            m.successors(&inits[0], &mut succs);
            assert!(!succs.is_empty());
            // after the tuning choice, WG/TS are observable
            assert!(m.eval_var(&succs[0], "WG").is_some());
            assert!(m.eval_var(&succs[0], "TS").is_some());
            let mut enc = Vec::new();
            m.encode(&succs[0], &mut enc);
            assert!(!enc.is_empty());
            assert!(!m.describe(&succs[0]).is_empty());
        }
    }

    #[test]
    fn optimum_time_matches_underlying_model() {
        let job = TuningJob::new(ModelKind::Minimum, 64);
        let m = MinModel::paper(64, 4).unwrap();
        assert_eq!(job.optimum_time().unwrap(), m.optimum().0);
    }
}
