//! Bench for the paper's Table 3: tuning the Minimum model for every
//! (PEs, data size) group, both methods, plus the Promela engine.

use mcautotune::checker::{check, CheckOptions};
use mcautotune::model::SafetyLtl;
use mcautotune::platform::MinModel;
use mcautotune::promela::{templates, PromelaSystem};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};
use mcautotune::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new("table3");
    let swarm = SwarmConfig {
        workers: 2,
        time_budget: Duration::from_millis(1500),
        ..Default::default()
    };
    for &(np, size) in &[(4u32, 16u32), (64, 64), (64, 128), (64, 256)] {
        let m = MinModel::paper(size, np).unwrap();
        b.bench(&format!("exhaustive/np{}/size{}", np, size), || {
            tune(&m, Method::Exhaustive, &CheckOptions::default(), &swarm, None).unwrap().t_min
        });
        b.bench(&format!("swarm/np{}/size{}", np, size), || {
            tune(&m, Method::Swarm, &CheckOptions::default(), &swarm, None).unwrap().t_min
        });
    }
    // Promela engine on the small group
    let sys = PromelaSystem::from_source(&templates::minimum_pml(16, 4, 3)).unwrap();
    let mut o = CheckOptions::default();
    o.collect_all = true;
    b.bench("promela-exhaustive/np4/size16", || {
        check(&sys, &SafetyLtl::non_termination(), &o).unwrap().violations.len()
    });
}
