//! Bench for the paper's Table 1: auto-tuning the abstract platform model
//! across input sizes, exhaustive (bisection) vs swarm, plus the Promela
//! engine on a small size for the SPIN-comparable cost.

use mcautotune::checker::{check, CheckOptions};
use mcautotune::model::SafetyLtl;
use mcautotune::platform::{AbstractModel, Granularity, PlatformConfig};
use mcautotune::promela::{templates, PromelaSystem};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};
use mcautotune::util::bench::Bencher;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new("table1");
    let plat = PlatformConfig::default();
    let swarm = SwarmConfig {
        workers: 2,
        time_budget: Duration::from_millis(1500),
        ..Default::default()
    };

    for &size in &[8u32, 32, 128, 512, 1024] {
        let m = AbstractModel::new(size, plat, Granularity::Phase).unwrap();
        b.bench(&format!("exhaustive/size{}", size), || {
            tune(&m, Method::Exhaustive, &CheckOptions::default(), &swarm, None).unwrap().t_min
        });
    }
    for &size in &[256u32, 1024] {
        let m = AbstractModel::new(size, plat, Granularity::Phase).unwrap();
        b.bench(&format!("swarm/size{}", size), || {
            tune(&m, Method::Swarm, &CheckOptions::default(), &swarm, None).unwrap().t_min
        });
    }
    // the SPIN-comparable column: full-interleaving Promela exhaustive
    for &size in &[8u32] {
        let sys = PromelaSystem::from_source(&templates::abstract_pml(
            size,
            &PlatformConfig { gmt: 2, ..plat },
        ))
        .unwrap();
        let mut o = CheckOptions::default();
        o.collect_all = true;
        b.bench(&format!("promela-exhaustive/size{}", size), || {
            check(&sys, &SafetyLtl::non_termination(), &o).unwrap().violations.len()
        });
    }
}
