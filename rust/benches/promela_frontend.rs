//! Promela front-end benchmarks: parse+compile throughput and the
//! interpreter's successor-generation rate (the §Perf reference-engine
//! hot path).

use mcautotune::checker::{check, CheckOptions};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::platform::PlatformConfig;
use mcautotune::promela::{templates, PromelaSystem};
use mcautotune::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new("promela");

    let src_min = templates::minimum_pml(16, 4, 3);
    let src_abs = templates::abstract_pml(8, &PlatformConfig { gmt: 2, ..Default::default() });

    b.bench_elems("parse+compile/minimum16", src_min.len() as u64, || {
        PromelaSystem::from_source(&src_min).unwrap().prog.procs.len()
    });

    // raw interleaving engine: transitions/s over an exhaustive run
    for (name, src) in [("minimum16", &src_min), ("abstract8-gmt2", &src_abs)] {
        let sys = PromelaSystem::from_source(src).unwrap();
        let p = SafetyLtl::parse("G(true)").unwrap();
        let trans = check(&sys, &p, &CheckOptions::default()).unwrap().stats.transitions;
        b.bench_elems(&format!("explore/{} ({} transitions)", name, trans), trans, || {
            check(&sys, &p, &CheckOptions::default()).unwrap().stats.transitions
        });
    }

    // successor generation on a fixed mid-run state
    let sys = PromelaSystem::from_source(&src_min).unwrap();
    let mut s = sys.initial_states().pop().unwrap();
    let mut buf = Vec::new();
    for _ in 0..200 {
        sys.successors(&s, &mut buf);
        if buf.is_empty() {
            break;
        }
        s = buf[0].clone();
    }
    b.bench("successors/mid-state", || {
        sys.successors(black_box(&s), &mut buf);
        buf.len()
    });
}
