//! Checker hot-path benchmarks: sequential vs parallel exhaustive search,
//! compiled vs interpreted property evaluation, and arena store inserts.
//!
//! Emits `BENCH_checker.json` (path override: `MCAT_BENCH_JSON`) so the
//! perf trajectory is tracked across PRs — run via `scripts/bench.sh`.
//! `MCAT_BENCH_SIZE` shrinks the model for smoke runs (CI uses 128);
//! `MCAT_BENCH_FAST=1` shrinks the measurement budget (see util::bench).

use mcautotune::checker::{
    check_parallel, check_sequential, CheckOptions, Compression, StoreKind, VisitedStore,
};
use mcautotune::model::{EvalScratch, SafetyLtl, TransitionSystem};
use mcautotune::platform::{enumerate_tunings, AbstractModel, Granularity, MinModel, PlatformConfig};
use mcautotune::promela::{templates, PromelaSystem, PromelaVm};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{harvest_observations, surrogate_tune, tune, Method, SurrogateOptions};
use mcautotune::util::bench::{black_box, Bencher};

fn env_u32(name: &str, default: u32) -> u32 {
    std::env::var(name).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

type AbsState = <AbstractModel as TransitionSystem>::State;

/// Breadth-first state corpus for the property-evaluation benches.
fn collect_states(m: &AbstractModel, limit: usize) -> Vec<AbsState> {
    let mut out = m.initial_states();
    let mut i = 0;
    let mut succs = Vec::new();
    while i < out.len() && out.len() < limit {
        let s = out[i];
        m.successors(&s, &mut succs);
        out.extend(succs.drain(..).take(limit - out.len()));
        i += 1;
    }
    out
}

/// Generic breadth-first corpus (no dedup — both Promela engines expand
/// in the identical order, so corpora correspond index-for-index).
fn bfs_corpus<M: TransitionSystem>(m: &M, limit: usize) -> Vec<M::State> {
    let mut out = m.initial_states();
    let mut i = 0;
    let mut succs = Vec::new();
    while i < out.len() && out.len() < limit {
        let s = out[i].clone();
        m.successors(&s, &mut succs);
        let room = limit - out.len();
        out.extend(succs.drain(..).take(room));
        i += 1;
    }
    out
}

fn main() {
    let size = env_u32("MCAT_BENCH_SIZE", 1024);
    let mut b = Bencher::new("checker_hot_path");

    // --- end-to-end exploration: sequential vs parallel (states/s) ------
    let m = AbstractModel::new(size, PlatformConfig::default(), Granularity::Tick).unwrap();
    let p = SafetyLtl::parse("G(true)").unwrap();
    let seq_opts = CheckOptions::default();
    let states = check_sequential(&m, &p, &seq_opts).unwrap().stats.states_stored;
    println!("model: abstract size={} tick — {} states", size, states);
    b.bench_elems("explore/seq", states, || {
        check_sequential(&m, &p, &seq_opts).unwrap().stats.states_stored
    });
    for threads in [2u32, 4, 8] {
        let o = CheckOptions { threads, ..CheckOptions::default() };
        let got = check_parallel(&m, &p, &o).unwrap().stats.states_stored;
        assert_eq!(got, states, "parallel explored a different state count");
        b.bench_elems(&format!("explore/par{}", threads), states, || {
            check_parallel(&m, &p, &o).unwrap().stats.states_stored
        });
    }

    // --- telemetry overhead: counters on vs. off ------------------------
    // explore/seq above ran with telemetry off (the default, and the
    // configuration the historical numbers pin); this rerun enables the
    // obs counter registry, and traced/seq becomes overhead_trace_vs_off
    // in BENCH_checker.json — the disabled path must stay within noise of
    // pre-telemetry builds, the enabled path within a few percent.
    mcautotune::obs::set_enabled(true);
    mcautotune::obs::metrics().reset();
    b.bench_elems("explore/traced", states, || {
        check_sequential(&m, &p, &seq_opts).unwrap().stats.states_stored
    });
    mcautotune::obs::set_enabled(false);

    // --- property monitor: compiled bytecode vs interpreted AST ---------
    let small = AbstractModel::new(size.min(256), PlatformConfig::default(), Granularity::Phase)
        .unwrap();
    let corpus = collect_states(&small, 20_000);
    let prop = SafetyLtl::parse("G(FIN -> time > 1000)").unwrap();
    let compiled = prop.compile(&small).unwrap();
    let mut scratch = EvalScratch::default();
    b.bench_elems("prop-eval/compiled", corpus.len() as u64, || {
        let mut holds = 0u64;
        for s in &corpus {
            holds += compiled.holds_state(&small, s, &mut scratch).unwrap() as u64;
        }
        holds
    });
    b.bench_elems("prop-eval/interpreted", corpus.len() as u64, || {
        let mut holds = 0u64;
        for s in &corpus {
            let lookup = |n: &str| small.eval_var(s, n);
            holds += prop.holds(&lookup).unwrap() as u64;
        }
        holds
    });

    // --- Promela successor generation: interpreter vs bytecode VM -------
    // (the engine=promela batch hot path; promela-succ/vm over interp is
    // the VM speedup tracked across PRs)
    let pml_size = size.clamp(4, 16); // promela state spaces explode past 16
    let pml_src = templates::minimum_pml(pml_size, 4, 3);
    let pml_interp = PromelaSystem::from_source(&pml_src).unwrap();
    let pml_vm = PromelaVm::from_source(&pml_src).unwrap();
    let interp_corpus = bfs_corpus(&pml_interp, 4_000);
    let vm_corpus = bfs_corpus(&pml_vm, 4_000);
    assert_eq!(
        interp_corpus.len(),
        vm_corpus.len(),
        "the two engines must expand identical corpora"
    );
    println!("promela: minimum size={} — {} corpus states", pml_size, interp_corpus.len());
    b.bench_elems("promela-succ/interp", interp_corpus.len() as u64, || {
        let mut buf = Vec::new();
        let mut n = 0u64;
        for s in &interp_corpus {
            pml_interp.successors(s, &mut buf);
            n += buf.len() as u64;
        }
        n
    });
    b.bench_elems("promela-succ/vm", vm_corpus.len() as u64, || {
        let mut buf = Vec::new();
        let mut n = 0u64;
        for s in &vm_corpus {
            pml_vm.successors(s, &mut buf);
            n += buf.len() as u64;
        }
        n
    });

    // --- static reductions: explore timings + states-stored ratios ------
    // (--por and --reduce dead-slots over the same minimum model; the
    // ratios are the reductions' coverage metric tracked across PRs —
    // 1.0 means the reduction degraded to a no-op)
    let pml_prop = SafetyLtl::parse("G(!FIN)").unwrap();
    let pml_base_states =
        check_sequential(&pml_vm, &pml_prop, &seq_opts).unwrap().stats.states_stored;
    let por_opts = CheckOptions { por: true, ..CheckOptions::default() };
    let por_states = check_sequential(&pml_vm, &pml_prop, &por_opts).unwrap().stats.states_stored;
    b.bench_elems("explore/por", por_states, || {
        check_sequential(&pml_vm, &pml_prop, &por_opts).unwrap().stats.states_stored
    });
    let pml_red = PromelaVm::from_source(&pml_src).unwrap().with_dead_slot_reduction();
    let deadslots_states =
        check_sequential(&pml_red, &pml_prop, &seq_opts).unwrap().stats.states_stored;
    b.bench_elems("explore/dead-slots", deadslots_states, || {
        check_sequential(&pml_red, &pml_prop, &seq_opts).unwrap().stats.states_stored
    });
    println!(
        "promela reductions: baseline {} states, por {}, dead-slots {}",
        pml_base_states, por_states, deadslots_states
    );

    // --- store regimes: COLLAPSE compression + disk spill ----------------
    // compression_bytes_ratio is collapse/full resident store bytes at
    // identical coverage (< 1.0 means the component interning pays);
    // spill_slowdown_ratio is spill/full explore time under a memory
    // budget low enough to force frozen runs to disk — the I/O price of
    // completing a search the in-RAM store could not.
    b.bench_elems("explore/pml-seq", pml_base_states, || {
        check_sequential(&pml_vm, &pml_prop, &seq_opts).unwrap().stats.states_stored
    });
    let full_rep = check_sequential(&pml_vm, &pml_prop, &seq_opts).unwrap();
    let col_opts = CheckOptions { compress: Compression::Collapse, ..CheckOptions::default() };
    let col_rep = check_sequential(&pml_vm, &pml_prop, &col_opts).unwrap();
    assert_eq!(
        col_rep.stats.states_stored, full_rep.stats.states_stored,
        "collapse changed coverage"
    );
    b.bench_elems("explore/collapse", pml_base_states, || {
        check_sequential(&pml_vm, &pml_prop, &col_opts).unwrap().stats.states_stored
    });
    let spill_dir = std::env::temp_dir().join(format!("mcat_bench_spill_{}", std::process::id()));
    std::fs::create_dir_all(&spill_dir).ok();
    let spill_opts = CheckOptions {
        store: StoreKind::Spill,
        spill_dir: Some(spill_dir.clone()),
        memory_budget: 512 << 10, // watermark 256 KiB: forces runs to disk
        ..CheckOptions::default()
    };
    let spill_rep = check_sequential(&pml_vm, &pml_prop, &spill_opts).unwrap();
    assert_eq!(
        spill_rep.stats.states_stored, full_rep.stats.states_stored,
        "spill changed coverage"
    );
    b.bench_elems("explore/spill", pml_base_states, || {
        check_sequential(&pml_vm, &pml_prop, &spill_opts).unwrap().stats.states_stored
    });
    std::fs::remove_dir_all(&spill_dir).ok();
    println!(
        "promela store regimes: full {} bytes, collapse {} bytes, spill {} resident bytes",
        full_rep.stats.bytes_used, col_rep.stats.bytes_used, spill_rep.stats.bytes_used
    );

    // --- tuner search modes: exhaustive bisection vs surrogate ----------
    // surrogate_eval_fraction is surrogate/exhaustive checker invocations
    // on a warm observation store (< 1.0 = the cache-seeded proposer
    // pays); the certificate guarantees the optima are identical, so the
    // pair measures pure search-strategy cost at equal answers.
    let tune_size = 64u32;
    let tm = MinModel::paper(tune_size, 4).unwrap();
    let sw = SwarmConfig::default();
    let t_ini = Some(1i64 << 17);
    let ex = tune(&tm, Method::Exhaustive, &seq_opts, &sw, t_ini).unwrap();
    let exhaustive_calls = ex.log.len() as u64; // one log line per Cex(T) query
    b.bench_elems("tune/exhaustive", exhaustive_calls, || {
        tune(&tm, Method::Exhaustive, &seq_opts, &sw, t_ini).unwrap().t_min as u64
    });
    // warm observation store: harvests from smaller sizes of the family
    let mut obs_seeds = Vec::new();
    for s in [16u32, 32] {
        let m = MinModel::paper(s, 4).unwrap();
        let r = tune(&m, Method::Exhaustive, &seq_opts, &sw, t_ini).unwrap();
        obs_seeds.extend(harvest_observations(&r, s));
    }
    obs_seeds.extend(harvest_observations(&ex, tune_size));
    let lattice = enumerate_tunings(tune_size).unwrap();
    let surr_cfg = SurrogateOptions::default();
    let rep =
        surrogate_tune(&tm, &seq_opts, &sw, t_ini, &lattice, tune_size, &obs_seeds, &surr_cfg)
            .unwrap();
    assert!(!rep.fell_back, "warm store must take the surrogate path");
    assert_eq!(rep.result.t_min, ex.t_min, "surrogate changed the optimum");
    let surrogate_calls = rep.oracle_calls;
    b.bench_elems("tune/surrogate", surrogate_calls, || {
        surrogate_tune(&tm, &seq_opts, &sw, t_ini, &lattice, tune_size, &obs_seeds, &surr_cfg)
            .unwrap()
            .result
            .t_min as u64
    });
    println!(
        "tuner search: exhaustive {} Cex queries, surrogate {} oracle calls (t_min {} both)",
        exhaustive_calls, surrogate_calls, ex.t_min
    );

    // --- arena Full-store inserts (fresh + duplicate probes) ------------
    let items: Vec<[u8; 24]> = (0..100_000u64)
        .map(|i| {
            let mut a = [0u8; 24];
            a[..8].copy_from_slice(&i.to_le_bytes());
            a[8..16].copy_from_slice(&(i ^ 0xABCD).to_le_bytes());
            a
        })
        .collect();
    b.bench_elems("store-insert/full-arena", 2 * items.len() as u64, || {
        let mut s = VisitedStore::new(StoreKind::Full);
        for it in &items {
            black_box(s.insert(it));
        }
        for it in &items {
            black_box(s.insert(it)); // duplicate probe path
        }
        s.len()
    });

    // --- BENCH_checker.json ---------------------------------------------
    let path = std::env::var("MCAT_BENCH_JSON").unwrap_or_else(|_| "../BENCH_checker.json".into());
    let mean_of = |name: &str| {
        b.results()
            .iter()
            .find(|r| r.name.ends_with(name))
            .map(|r| r.mean.as_secs_f64())
    };
    let speedup4 = match (mean_of("explore/seq"), mean_of("explore/par4")) {
        (Some(s), Some(p4)) if p4 > 0.0 => s / p4,
        _ => 0.0,
    };
    let vm_speedup = match (mean_of("promela-succ/interp"), mean_of("promela-succ/vm")) {
        (Some(i), Some(v)) if v > 0.0 => i / v,
        _ => 0.0,
    };
    let trace_overhead = match (mean_of("explore/seq"), mean_of("explore/traced")) {
        (Some(s), Some(t)) if s > 0.0 => t / s,
        _ => 0.0,
    };
    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"checker_hot_path\",\n");
    json.push_str(&format!("  \"model\": \"abstract size={} tick\",\n", size));
    json.push_str(&format!("  \"states\": {},\n", states));
    json.push_str(&format!("  \"speedup_par4_vs_seq\": {:.3},\n", speedup4));
    json.push_str(&format!("  \"speedup_promela_vm_vs_interp\": {:.3},\n", vm_speedup));
    json.push_str(&format!("  \"overhead_trace_vs_off\": {:.3},\n", trace_overhead));
    let ratio = |reduced: u64| {
        if pml_base_states > 0 { reduced as f64 / pml_base_states as f64 } else { 0.0 }
    };
    json.push_str(&format!(
        "  \"reduction_por_states_ratio\": {:.3},\n",
        ratio(por_states)
    ));
    json.push_str(&format!(
        "  \"reduction_deadslots_states_ratio\": {:.3},\n",
        ratio(deadslots_states)
    ));
    let compression_bytes_ratio = if full_rep.stats.bytes_used > 0 {
        col_rep.stats.bytes_used as f64 / full_rep.stats.bytes_used as f64
    } else {
        0.0
    };
    let spill_slowdown = match (mean_of("explore/pml-seq"), mean_of("explore/spill")) {
        (Some(f), Some(s)) if f > 0.0 => s / f,
        _ => 0.0,
    };
    json.push_str(&format!(
        "  \"compression_bytes_ratio\": {:.3},\n",
        compression_bytes_ratio
    ));
    json.push_str(&format!("  \"spill_slowdown_ratio\": {:.3},\n", spill_slowdown));
    let surrogate_eval_fraction = if exhaustive_calls > 0 {
        surrogate_calls as f64 / exhaustive_calls as f64
    } else {
        0.0
    };
    json.push_str(&format!(
        "  \"surrogate_eval_fraction\": {:.3},\n",
        surrogate_eval_fraction
    ));
    json.push_str("  \"results\": [\n");
    let n = b.results().len();
    for (i, r) in b.results().iter().enumerate() {
        let thrpt = r
            .elements
            .map(|e| e as f64 / r.mean.as_secs_f64())
            .unwrap_or(0.0);
        json.push_str(&format!(
            "    {{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {}, \"per_sec\": {:.1}}}{}\n",
            r.name,
            r.iters,
            r.mean.as_nanos(),
            thrpt,
            if i + 1 < n { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write(&path, &json) {
        Ok(()) => println!("wrote {}", path),
        Err(e) => eprintln!("could not write {}: {}", path, e),
    }
}
