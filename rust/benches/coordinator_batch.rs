//! Coordinator benchmarks: batch throughput vs worker count, sharding
//! overhead vs single-shot tuning, the pure cache-hit path, and raw
//! work-stealing queue overhead.

use mcautotune::coordinator::{run_batch, BatchOptions, JobQueue, ModelKind, ResultCache, TuningJob};
use mcautotune::util::bench::Bencher;

fn bench_jobs() -> Vec<TuningJob> {
    let mut jobs = Vec::new();
    for size in [16u32, 32, 64] {
        let mut j = TuningJob::new(ModelKind::Minimum, size);
        j.shards = 4;
        jobs.push(j);
    }
    let mut j = TuningJob::new(ModelKind::Abstract, 32);
    j.shards = 4;
    jobs.push(j);
    jobs
}

fn main() {
    let mut b = Bencher::new("coordinator");
    let jobs = bench_jobs();

    // batch scaling: same job set, 1 vs 4 queue workers (cold cache)
    for workers in [1u32, 4] {
        let opts = BatchOptions { workers, ..BatchOptions::default() };
        b.bench(&format!("batch-cold/{}-jobs/workers{}", jobs.len(), workers), || {
            let mut cache = ResultCache::in_memory();
            run_batch(&jobs, &opts, &mut cache).unwrap().total_states()
        });
    }

    // sharding overhead: 1 shard vs 4 shards at fixed worker count
    for shards in [1u32, 4] {
        let mut sharded = bench_jobs();
        for j in &mut sharded {
            j.shards = shards;
        }
        let opts = BatchOptions { workers: 4, ..BatchOptions::default() };
        b.bench(&format!("batch-cold/shards{}", shards), || {
            let mut cache = ResultCache::in_memory();
            run_batch(&sharded, &opts, &mut cache).unwrap().total_states()
        });
    }

    // the cache-hit path: every job served without verification
    let opts = BatchOptions::default();
    let mut warm_cache = ResultCache::in_memory();
    run_batch(&jobs, &opts, &mut warm_cache).unwrap();
    b.bench_elems("batch-warm-cache-hits", jobs.len() as u64, || {
        run_batch(&jobs, &opts, &mut warm_cache).unwrap().cache_hits
    });

    // raw queue overhead on no-op tasks
    let q = JobQueue::new(4);
    b.bench_elems("queue/noop-tasks", 10_000, || {
        q.run((0..10_000u32).collect(), |x| x).len()
    });
}
