//! Checker micro-benchmarks: raw state-exploration throughput (the §Perf
//! L3 hot path), store insert rates, and property-evaluation overhead.

use mcautotune::checker::{check, CheckOptions, StoreKind, VisitedStore};
use mcautotune::model::SafetyLtl;
use mcautotune::platform::{AbstractModel, Granularity, MinModel, PlatformConfig};
use mcautotune::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::new("checker");

    // end-to-end exploration rate on the native models (states/s)
    let m = AbstractModel::new(256, PlatformConfig::default(), Granularity::Phase).unwrap();
    let p = SafetyLtl::parse("G(true)").unwrap();
    let states = check(&m, &p, &CheckOptions::default()).unwrap().stats.states_stored;
    b.bench_elems(&format!("explore/abstract256-phase ({} states)", states), states, || {
        check(&m, &p, &CheckOptions::default()).unwrap().stats.states_stored
    });

    let mt = AbstractModel::new(64, PlatformConfig::default(), Granularity::Tick).unwrap();
    let states = check(&mt, &p, &CheckOptions::default()).unwrap().stats.states_stored;
    b.bench_elems(&format!("explore/abstract64-tick ({} states)", states), states, || {
        check(&mt, &p, &CheckOptions::default()).unwrap().stats.states_stored
    });

    let mm = MinModel::paper(256, 64).unwrap();
    let states = check(&mm, &p, &CheckOptions::default()).unwrap().stats.states_stored;
    b.bench_elems(&format!("explore/minimum256 ({} states)", states), states, || {
        check(&mm, &p, &CheckOptions::default()).unwrap().stats.states_stored
    });

    // store insert throughput (100k distinct 24-byte states)
    let items: Vec<[u8; 24]> = (0..100_000u64)
        .map(|i| {
            let mut a = [0u8; 24];
            a[..8].copy_from_slice(&i.to_le_bytes());
            a[8..16].copy_from_slice(&(i ^ 0xABCD).to_le_bytes());
            a
        })
        .collect();
    for kind in [
        StoreKind::Full,
        StoreKind::HashCompact,
        StoreKind::Bitstate { log2_bits: 24, hashes: 3 },
    ] {
        b.bench_elems(&format!("store-insert/{}", kind.name()), items.len() as u64, || {
            let mut s = VisitedStore::new(kind);
            for it in &items {
                black_box(s.insert(it));
            }
            s.len()
        });
    }
}
