//! Bench for the paper's Table 2: the Minimum Pallas kernel executed via
//! PJRT for every tuning configuration in the sweep. Prints the same
//! (global size, WG, TS) -> ms / GB/s rows the paper reports.
//!
//! Requires `make artifacts`.

use mcautotune::opencl::gen_data;
use mcautotune::runtime::Engine;
use mcautotune::util::bench::Bencher;

fn main() {
    let dir = Engine::default_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("table2 bench skipped: artifacts not built (run `make artifacts`)");
        return;
    }
    let mut engine = Engine::new(&dir).unwrap();
    let entries: Vec<_> = engine
        .manifest()
        .of_kind("min_device")
        .filter(|e| !e.name.ends_with("_small"))
        .cloned()
        .collect();
    let n = entries[0].size as usize;
    let data = gen_data(n, 42);
    let expected = *data.iter().min().unwrap();
    let bytes = (n * 4) as u64;

    let mut b = Bencher::new("table2");
    for e in &entries {
        // warm-up compiles the executable outside the timed region
        let out = engine.run_min(&e.name, &data).unwrap();
        assert_eq!(out.global_min, expected, "{} wrong", e.name);
        b.bench_elems(
            &format!("g{}/wg{}/ts{}", e.units * e.wg, e.wg, e.ts),
            bytes,
            || engine.run_min(&e.name, &data).unwrap().global_min,
        );
    }
    println!("\n(bandwidth: thrpt column is bytes/s over the {} B input)", bytes);
}
