//! Checker-scale acceptance tests (ISSUE 9): COLLAPSE compression must
//! strictly shrink the visited-store footprint without changing any
//! observable result, and the spillable store must complete — with an
//! identical verdict and trail — a run whose in-RAM twin exceeds the
//! memory budget (graceful OOM degradation instead of `MemoryLimit`).

use mcautotune::checker::{check, Abort, CheckOptions, Compression, StoreKind};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::promela::{templates, PromelaVm};

/// Full corpus-model exploration (property violated at every FIN state,
/// collect_all so the whole space is swept) under three regimes:
/// unbounded full store (the baseline), budget-bounded full store (must
/// die), budget-bounded spill store (must finish and match the baseline).
#[test]
fn spill_completes_where_the_in_ram_store_exceeds_the_budget() {
    let src = templates::minimum_pml(32, 4, 3);
    let prop = SafetyLtl::parse("G(!FIN)").unwrap();
    let vm = PromelaVm::from_source(&src).unwrap();

    let unbounded = CheckOptions { collect_all: true, ..CheckOptions::default() };
    let baseline = check(&vm, &prop, &unbounded).unwrap();
    assert!(baseline.exhausted && baseline.found());
    // two preconditions for the bounded twin to die: the sweep must
    // outgrow the budget, and must store enough states for the DFS's
    // amortized (every-4096-stores) budget check to fire at all
    assert!(
        baseline.stats.bytes_used > 512 * 1024,
        "model must outgrow the bounded budget for this test to bite ({} bytes)",
        baseline.stats.bytes_used
    );
    assert!(
        baseline.stats.states_stored > 4096,
        "model must cross the amortized budget checkpoint ({} states)",
        baseline.stats.states_stored
    );

    let mut bounded = unbounded.clone();
    bounded.memory_budget = 512 * 1024;
    let full = check(&vm, &prop, &bounded).unwrap();
    assert_eq!(full.stats.abort, Some(Abort::MemoryLimit), "in-RAM twin must die");
    assert!(!full.exhausted);

    let dir = std::env::temp_dir().join(format!("mcat_oom_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut spill = bounded.clone();
    spill.store = StoreKind::Spill;
    spill.spill_dir = Some(dir.clone());
    let sp = check(&vm, &prop, &spill).unwrap();
    assert!(sp.exhausted, "spill must absorb the overflow: {:?}", sp.stats.abort);
    assert_eq!(sp.stats.states_stored, baseline.stats.states_stored);
    assert_eq!(sp.stats.states_matched, baseline.stats.states_matched);
    assert_eq!(sp.stats.transitions, baseline.stats.transitions);
    assert_eq!(sp.violations.len(), baseline.violations.len());
    for (vb, vs) in baseline.violations.iter().zip(&sp.violations) {
        assert_eq!(vb.depth, vs.depth, "violation depths match");
        assert_eq!(vb.trail.states.len(), vs.trail.states.len());
        for (sb, ss) in vb.trail.states.iter().zip(&vs.trail.states) {
            assert_eq!(vm.describe(sb), vm.describe(ss), "trail states match");
        }
    }
    // RAM-resident footprint respected the regime: far below the baseline
    assert!(
        sp.stats.bytes_used < baseline.stats.bytes_used,
        "spill resident bytes {} must undercut the full store's {}",
        sp.stats.bytes_used,
        baseline.stats.bytes_used
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The anti-no-op pin for `--compress collapse`: on minimum-8 (flat
/// packed frames repeat heavily across states) the compressed store's
/// peak footprint must be *strictly* below the full store's, while every
/// search statistic stays identical. The sequential store only ever
/// grows, so the end-of-run `bytes_used` is the peak.
#[test]
fn collapse_strictly_shrinks_the_store_on_minimum_8() {
    let src = templates::minimum_pml(8, 4, 3);
    let prop = SafetyLtl::parse("G(!FIN)").unwrap();
    let vm = PromelaVm::from_source(&src).unwrap();
    let base_opts = CheckOptions { collect_all: true, ..CheckOptions::default() };
    let col_opts = CheckOptions { compress: Compression::Collapse, ..base_opts.clone() };

    let base = check(&vm, &prop, &base_opts).unwrap();
    let col = check(&vm, &prop, &col_opts).unwrap();
    assert_eq!(base.exhausted, col.exhausted);
    assert_eq!(base.stats.states_stored, col.stats.states_stored);
    assert_eq!(base.stats.states_matched, col.stats.states_matched);
    assert_eq!(base.stats.transitions, col.stats.transitions);
    assert_eq!(base.violations.len(), col.violations.len());
    assert!(
        col.stats.bytes_used < base.stats.bytes_used,
        "collapse must strictly shrink store.bytes_peak ({} vs {})",
        col.stats.bytes_used,
        base.stats.bytes_used
    );
}

/// Region-aware hash-compact (`--store compact --compress collapse`):
/// equivalent verdict and counts to both the full baseline and the exact
/// collapse store on a collision-free space, with a footprint at or below
/// the exact collapse store's (it keeps the component tables but replaces
/// the per-state tuple copy with one 8-byte hash).
#[test]
fn compact_collapse_matches_exact_stores_on_minimum_8() {
    let src = templates::minimum_pml(8, 4, 3);
    let prop = SafetyLtl::parse("G(!FIN)").unwrap();
    let vm = PromelaVm::from_source(&src).unwrap();
    let base_opts = CheckOptions { collect_all: true, ..CheckOptions::default() };
    let col_opts = CheckOptions { compress: Compression::Collapse, ..base_opts.clone() };
    let cc_opts = CheckOptions { store: StoreKind::HashCompact, ..col_opts.clone() };

    let base = check(&vm, &prop, &base_opts).unwrap();
    let col = check(&vm, &prop, &col_opts).unwrap();
    let cc = check(&vm, &prop, &cc_opts).unwrap();
    assert_eq!(base.exhausted, cc.exhausted);
    assert_eq!(base.stats.states_stored, cc.stats.states_stored);
    assert_eq!(base.stats.states_matched, cc.stats.states_matched);
    assert_eq!(base.stats.transitions, cc.stats.transitions);
    assert_eq!(base.violations.len(), cc.violations.len());
    assert!(
        cc.stats.bytes_used <= col.stats.bytes_used,
        "compact+collapse must not exceed exact collapse ({} vs {})",
        cc.stats.bytes_used,
        col.stats.bytes_used
    );
    assert!(
        cc.stats.bytes_used < base.stats.bytes_used,
        "compact+collapse must strictly shrink store.bytes_peak ({} vs {})",
        cc.stats.bytes_used,
        base.stats.bytes_used
    );
}

/// Collapse on a model without a native region split (the default
/// single-region `encode_regions`) stays exact: same results, and the
/// indirection overhead is bounded (tuple table + one component per
/// distinct state).
#[test]
fn collapse_without_a_region_split_stays_exact() {
    let src = "int x;\nactive proctype main() { run a(); run b() }\n\
               proctype a() { x = 1 }\nproctype b() { x = 2 }";
    // the interpreter keeps the default encode_regions (one region)
    let interp = mcautotune::promela::PromelaSystem::from_source(src).unwrap();
    let prop = SafetyLtl::parse("G(x != 2)").unwrap();
    let base_opts = CheckOptions { collect_all: true, ..CheckOptions::default() };
    let col_opts = CheckOptions { compress: Compression::Collapse, ..base_opts.clone() };
    let base = check(&interp, &prop, &base_opts).unwrap();
    let col = check(&interp, &prop, &col_opts).unwrap();
    assert_eq!(base.stats.states_stored, col.stats.states_stored);
    assert_eq!(base.found(), col.found());
    assert_eq!(base.exhausted, col.exhausted);
}
