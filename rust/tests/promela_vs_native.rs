//! Equivalence of the two engines (DESIGN.md §6): the full-interleaving
//! Promela models and the canonical-schedule native models must agree on
//! the reachable terminal observations for every tuning choice, and the
//! tuner must find the same optimum through either engine.

use mcautotune::checker::{check, CheckOptions};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::platform::{
    AbstractModel, DataInit, Granularity, MinModel, PlatformConfig,
};
use mcautotune::promela::{templates, PromelaSystem};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};
use std::collections::BTreeSet;

fn fin_set(sys: &PromelaSystem, with_result: bool) -> BTreeSet<(i64, i64, i64, i64)> {
    let mut o = CheckOptions::default();
    o.collect_all = true;
    let rep = check(sys, &SafetyLtl::non_termination(), &o).unwrap();
    assert!(rep.exhausted);
    rep.violations
        .iter()
        .map(|v| {
            let s = v.trail.last();
            (
                sys.eval_var(s, "WG").unwrap(),
                sys.eval_var(s, "TS").unwrap(),
                sys.eval_var(s, "time").unwrap(),
                if with_result { sys.eval_var(s, "result").unwrap() } else { 0 },
            )
        })
        .collect()
}

#[test]
fn minimum_models_agree_size32() {
    // full size in release; debug builds interpret ~30x slower, so shrink
    let size = if cfg!(debug_assertions) { 16 } else { 32 };
    let (np, gmt) = (4, 3);
    let sys = PromelaSystem::from_source(&templates::minimum_pml(size, np, gmt)).unwrap();
    let native = MinModel::new(size, np, gmt, DataInit::Descending, Granularity::Phase).unwrap();
    let got = fin_set(&sys, true);
    let want: BTreeSet<_> = native
        .tunings()
        .iter()
        .map(|&t| {
            (t.wg as i64, t.ts as i64, native.predicted_time(t) as i64, native.true_min() as i64)
        })
        .collect();
    assert_eq!(got, want);
}

#[test]
fn minimum_models_agree_np_exceeding_wg() {
    // NP=8 > some WGs: exercises the NWE clamp in both engines
    let (size, np, gmt) = (16, 8, 2);
    let sys = PromelaSystem::from_source(&templates::minimum_pml(size, np, gmt)).unwrap();
    let native = MinModel::new(size, np, gmt, DataInit::Descending, Granularity::Phase).unwrap();
    let got = fin_set(&sys, true);
    let want: BTreeSet<_> = native
        .tunings()
        .iter()
        .map(|&t| {
            (t.wg as i64, t.ts as i64, native.predicted_time(t) as i64, native.true_min() as i64)
        })
        .collect();
    assert_eq!(got, want);
}

#[test]
fn abstract_models_agree_size16() {
    let plat = PlatformConfig { nd: 1, nu: 1, np: 4, gmt: 2 };
    let sys = PromelaSystem::from_source(&templates::abstract_pml(16, &plat)).unwrap();
    let native = AbstractModel::new(16, plat, Granularity::Phase).unwrap();
    let got = fin_set(&sys, false);
    let want: BTreeSet<_> = native
        .tunings()
        .iter()
        .map(|&t| (t.wg as i64, t.ts as i64, native.predicted_time(t) as i64, 0))
        .collect();
    assert_eq!(got, want);
}

#[test]
fn tuner_finds_same_optimum_through_either_engine() {
    let (size, np, gmt) = (16, 4, 3);
    let sys = PromelaSystem::from_source(&templates::minimum_pml(size, np, gmt)).unwrap();
    let native = MinModel::new(size, np, gmt, DataInit::Descending, Granularity::Phase).unwrap();
    let co = CheckOptions::default();
    let sw = SwarmConfig::default();
    let r_pml = tune(&sys, Method::Exhaustive, &co, &sw, Some(10_000)).unwrap();
    let r_nat = tune(&native, Method::Exhaustive, &co, &sw, Some(10_000)).unwrap();
    assert_eq!(r_pml.t_min, r_nat.t_min);
    // Promela search is orders of magnitude larger — that's the point of
    // the native fast path (recorded in EXPERIMENTS.md §Perf)
    assert!(r_pml.states_explored > r_nat.states_explored * 10);
}

#[test]
fn shipped_model_files_compile_and_verify() {
    // models/*.pml as written by `gen-models` — parse, compile, quick check
    for (name, src) in [
        ("abstract_8", templates::abstract_pml(8, &PlatformConfig::default())),
        ("minimum_16", templates::minimum_pml(16, 4, 3)),
    ] {
        let sys = PromelaSystem::from_source(&src)
            .unwrap_or_else(|e| panic!("{} failed to compile: {}", name, e));
        let rep = check(&sys, &SafetyLtl::non_termination(), &CheckOptions::default()).unwrap();
        assert!(rep.found(), "{}: must have terminating runs", name);
    }
}
