//! Differential conformance suite: the bytecode VM over flat packed
//! states (`promela::vm::PromelaVm`) pinned against the reference
//! tree-walking interpreter (`promela::interp::PromelaSystem`) — and the
//! shard-specialized VM pinned against the generic `ShardModel`
//! re-filtering path.
//!
//! Both engines execute the same stage-one automaton, so their state
//! spaces correspond one-to-one: for every corpus model the verdict, the
//! stored/matched/transition counts, the violation sequence and every
//! trail (compared state-by-state through `describe`) must be identical
//! under sequential DFS *and* the deterministic parallel frontier. A
//! property test additionally pins bytecode expression evaluation
//! (constant folding, short-circuit jumps, conditional expressions) to
//! the tree-walk on generated expression trees.

use mcautotune::checker::{check, CheckOptions, Compression, Frontier, StoreKind};
use mcautotune::coordinator::{
    merge_results, plan_batch, run_batch, BatchOptions, JobEngine, JobModel, ModelKind,
    ResultCache, ShardModel, TuningJob,
};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::platform::PlatformConfig;
use mcautotune::promela::{templates, PromelaSystem, PromelaVm};
use mcautotune::prop_assert_eq;
use mcautotune::tuner::{tune, Method};
use mcautotune::util::prop::{forall, Config};
use mcautotune::util::rng::Xoshiro256;

/// The example corpus: every semantic feature of the subset, plus the
/// paper's two generated models, each with a property that exercises
/// trail extraction where the model can violate one.
fn corpus() -> Vec<(&'static str, String, &'static str)> {
    vec![
        (
            "seq-assign",
            "int a; int b; active proctype main() { a = 2; b = a + 3 }".into(),
            "G(true)",
        ),
        (
            "select",
            "int x; byte i; active proctype main() { select (i : 1 .. 3); x = i * 10 }".into(),
            "G(x != 20)",
        ),
        (
            "do-break",
            "int i; active proctype main() { do :: i < 5 -> i++ :: else -> break od }".into(),
            "G(i < 5)",
        ),
        (
            "arrays",
            "int a[4]; int s; byte i; active proctype main() {\
               for (i : 0 .. 3) { a[i] = i * i }\
               for (i : 0 .. 3) { s = s + a[i] } }"
                .into(),
            "G(s != 14)",
        ),
        (
            "rendezvous",
            "mtype = {go, done};\nchan c = [0] of {mtype};\nint got;\n\
             active proctype main() { run w(); c ! go; c ? done }\n\
             proctype w() { c ? go; got = 1; c ! done }"
                .into(),
            "G(got == 0)",
        ),
        (
            "rendezvous-match",
            "mtype = {go, stop};\nchan c = [0] of {mtype};\nint path;\n\
             active proctype main() { run w(); c ! go }\n\
             proctype w() { if :: c ? go -> path = 1 :: c ? stop -> path = 2 fi }"
                .into(),
            "G(path == 0)",
        ),
        (
            "buffered-fifo",
            "chan c = [2] of {byte};\nint a; int b;\n\
             active proctype main() { c ! 1; c ! 2; run w() }\n\
             proctype w() { byte x; c ? x; a = x; c ? x; b = x }"
                .into(),
            "G(b != 2)",
        ),
        (
            "else-choice",
            "int x = 1; int r;\n\
             active proctype main() { if :: x == 1 -> r = 10 :: else -> r = 20 fi }"
                .into(),
            "G(true)",
        ),
        (
            "interleave-race",
            "int x;\nactive proctype main() { run a(); run b() }\n\
             proctype a() { x = 1 }\nproctype b() { x = 2 }"
                .into(),
            "G(x != 2)",
        ),
        (
            "atomic-increment",
            "int x;\nactive proctype main() { run a(); run b() }\n\
             proctype a() { int t; atomic { t = x; x = t + 1 } }\n\
             proctype b() { int t; atomic { t = x; x = t + 1 } }"
                .into(),
            "G(x != 2)",
        ),
        (
            "blocking-guard",
            "int flag; int r;\n\
             active proctype main() { run setter(); flag == 1; r = 99 }\n\
             proctype setter() { flag = 1 }"
                .into(),
            "G(r != 99)",
        ),
        (
            "deadlock",
            "chan c = [0] of {byte};\nint r;\nactive proctype main() { byte x; c ? x; r = 1 }"
                .into(),
            "G(true)",
        ),
        (
            "local-chan",
            "int got;\n\
             active proctype main() { chan c = [1] of {byte}; c ! 9; byte x; c ? x; got = x }"
                .into(),
            "G(got != 9)",
        ),
        (
            "byte-wrap",
            "byte k = 200; int laps;\n\
             active proctype main() { do :: k != 0 -> k++ :: else -> break od; laps = 1 }"
                .into(),
            "G(!(k == 0 && laps == 1))",
        ),
        (
            "clock-mini",
            r#"
            int time; int nrp; int active_n = 2; bool FIN;
            active proctype main() { atomic { run p(); run p(); run clock() } }
            proctype p() {
              byte k; int cur;
              for (k : 0 .. 2) {
                atomic { cur = time; nrp = nrp + 1 };
                time > cur
              };
              atomic { active_n = active_n - 1; FIN = (active_n == 0 -> 1 : 0) }
            }
            proctype clock() {
              do
              :: FIN -> break
              :: !FIN && nrp >= active_n && active_n > 0 ->
                   atomic { nrp = 0; time = time + 1 }
              od
            }
            "#
            .into(),
            "G(FIN -> time > 3)",
        ),
        ("minimum-8", templates::minimum_pml(8, 4, 3), "G(!FIN)"),
        (
            "abstract-8",
            templates::abstract_pml(8, &PlatformConfig { nd: 1, nu: 1, np: 2, gmt: 2 }),
            "G(!FIN)",
        ),
    ]
}

/// Run both engines under `opts` and assert report + trail identity.
fn assert_engines_agree(
    name: &str,
    label: &str,
    interp: &PromelaSystem,
    vm: &PromelaVm,
    prop: &SafetyLtl,
    opts: &CheckOptions,
) {
    let ri = check(interp, prop, opts).unwrap();
    let rv = check(vm, prop, opts).unwrap();
    assert_eq!(ri.exhausted, rv.exhausted, "{}/{}: exhausted", name, label);
    assert_eq!(
        ri.stats.states_stored, rv.stats.states_stored,
        "{}/{}: states_stored",
        name, label
    );
    assert_eq!(
        ri.stats.states_matched, rv.stats.states_matched,
        "{}/{}: states_matched",
        name, label
    );
    assert_eq!(
        ri.stats.transitions, rv.stats.transitions,
        "{}/{}: transitions",
        name, label
    );
    assert_eq!(
        ri.violations.len(),
        rv.violations.len(),
        "{}/{}: violation count",
        name,
        label
    );
    for (k, (vi, vv)) in ri.violations.iter().zip(&rv.violations).enumerate() {
        assert_eq!(vi.depth, vv.depth, "{}/{}: violation {} depth", name, label, k);
        assert_eq!(
            vi.trail.states.len(),
            vv.trail.states.len(),
            "{}/{}: violation {} trail length",
            name,
            label,
            k
        );
        for (si, sv) in vi.trail.states.iter().zip(&vv.trail.states) {
            assert_eq!(
                interp.describe(si),
                vm.describe(sv),
                "{}/{}: violation {} trail state",
                name,
                label,
                k
            );
        }
    }
}

#[test]
fn vm_matches_interpreter_on_the_full_corpus() {
    for (name, src, prop) in corpus() {
        let interp = PromelaSystem::from_source(&src).unwrap();
        let vm = PromelaVm::from_source(&src).unwrap();
        let prop = SafetyLtl::parse(prop).unwrap();
        let dfs = CheckOptions { collect_all: true, ..CheckOptions::default() };
        assert_engines_agree(name, "dfs", &interp, &vm, &prop, &dfs);
        let det = CheckOptions {
            collect_all: true,
            threads: 4,
            frontier: Frontier::Deterministic,
            ..CheckOptions::default()
        };
        assert_engines_agree(name, "det4", &interp, &vm, &prop, &det);
        // first-trail identity under the default early-stop search
        assert_engines_agree(name, "first", &interp, &vm, &prop, &CheckOptions::default());
    }
}

#[test]
fn vm_matches_interpreter_without_atomic_coalescing() {
    let src = "int x;\nactive proctype main() { run a(); run b() }\n\
               proctype a() { int t; atomic { t = x; x = t + 1 } }\n\
               proctype b() { int t; atomic { t = x; x = t + 1 } }";
    let interp = PromelaSystem::from_source(src).unwrap().without_atomic_coalescing();
    let vm = PromelaVm::from_source(src).unwrap().without_atomic_coalescing();
    let prop = SafetyLtl::parse("G(x != 2)").unwrap();
    let opts = CheckOptions { collect_all: true, ..CheckOptions::default() };
    assert_engines_agree("atomic-stepwise", "dfs", &interp, &vm, &prop, &opts);
}

// --------------------------------------------------- exact store regimes --

/// `--compress collapse` and `--store spill` are *exact* store regimes:
/// on the full corpus each must reproduce the baseline full-store report
/// — verdict, state counts, violation sequence and every trail — and the
/// two engines must still agree with each other under the regime.
/// Collapse additionally runs under the deterministic parallel frontier
/// (its per-shard component stores); spill is sequential-only.
#[test]
fn collapse_and_spill_match_the_baseline_on_the_full_corpus() {
    let dir = std::env::temp_dir().join(format!("mcat_spill_corpus_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let dfs = CheckOptions { collect_all: true, ..CheckOptions::default() };
    let det4 = CheckOptions {
        collect_all: true,
        threads: 4,
        frontier: Frontier::Deterministic,
        ..CheckOptions::default()
    };
    for (name, src, prop) in corpus() {
        let interp = PromelaSystem::from_source(&src).unwrap();
        let vm = PromelaVm::from_source(&src).unwrap();
        let prop = SafetyLtl::parse(prop).unwrap();
        let base = check(&vm, &prop, &dfs).unwrap();
        for (label, opts) in [
            ("collapse", CheckOptions { compress: Compression::Collapse, ..dfs.clone() }),
            ("collapse-det4", CheckOptions { compress: Compression::Collapse, ..det4.clone() }),
            (
                "spill",
                CheckOptions {
                    store: StoreKind::Spill,
                    spill_dir: Some(dir.clone()),
                    ..dfs.clone()
                },
            ),
        ] {
            assert_engines_agree(name, label, &interp, &vm, &prop, &opts);
            let r = check(&vm, &prop, &opts).unwrap();
            assert_eq!(base.exhausted, r.exhausted, "{}/{}: exhausted", name, label);
            assert_eq!(
                base.stats.states_stored, r.stats.states_stored,
                "{}/{}: states_stored",
                name, label
            );
            assert_eq!(
                base.stats.states_matched, r.stats.states_matched,
                "{}/{}: states_matched",
                name, label
            );
            assert_eq!(
                base.violations.len(),
                r.violations.len(),
                "{}/{}: violation count",
                name,
                label
            );
            for (k, (vb, vr)) in base.violations.iter().zip(&r.violations).enumerate() {
                assert_eq!(vb.depth, vr.depth, "{}/{}: violation {} depth", name, label, k);
                assert_eq!(
                    vb.trail.states.len(),
                    vr.trail.states.len(),
                    "{}/{}: violation {} trail length",
                    name,
                    label,
                    k
                );
                for (sb, sr) in vb.trail.states.iter().zip(&vr.trail.states) {
                    assert_eq!(
                        vm.describe(sb),
                        vm.describe(sr),
                        "{}/{}: violation {} trail state",
                        name,
                        label,
                        k
                    );
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The regimes also leave the tuner's answer untouched (trail extraction
/// walks the same violations, so the optimum cannot move).
#[test]
fn store_regimes_preserve_the_tuning_optimum() {
    let src = templates::minimum_pml(8, 4, 3);
    let swarm = mcautotune::swarm::SwarmConfig::default();
    let base = tune(
        &PromelaVm::from_source(&src).unwrap(),
        Method::Exhaustive,
        &CheckOptions::default(),
        &swarm,
        Some(10_000),
    )
    .unwrap();
    let want = (base.optimal.wg, base.optimal.ts, base.t_min, base.states_explored);
    let dir = std::env::temp_dir().join(format!("mcat_spill_tune_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for (label, opts) in [
        (
            "collapse",
            CheckOptions { compress: Compression::Collapse, ..CheckOptions::default() },
        ),
        (
            "spill",
            CheckOptions {
                store: StoreKind::Spill,
                spill_dir: Some(dir.clone()),
                ..CheckOptions::default()
            },
        ),
    ] {
        let r = tune(
            &PromelaVm::from_source(&src).unwrap(),
            Method::Exhaustive,
            &opts,
            &swarm,
            Some(10_000),
        )
        .unwrap();
        assert_eq!(
            (r.optimal.wg, r.optimal.ts, r.t_min, r.states_explored),
            want,
            "{}: tuning result",
            label
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------- expression equivalence --

/// Random total expression over two int globals (division and modulo use
/// nonzero constant denominators so neither engine can fault — fault
/// equivalence has its own test in `promela::vm`).
fn gen_expr(r: &mut Xoshiro256, depth: u32) -> String {
    if depth == 0 || r.below(4) == 0 {
        return match r.below(4) {
            0 => format!("{}", r.range_i64(-30, 30)),
            1 => "g0".to_string(),
            2 => "g1".to_string(),
            _ => format!("{}", r.range_i64(0, 5)),
        };
    }
    match r.below(18) {
        0 => format!("(!{})", gen_expr(r, depth - 1)),
        1 => format!("(-{})", gen_expr(r, depth - 1)),
        2 => format!(
            "({} -> {} : {})",
            gen_expr(r, depth - 1),
            gen_expr(r, depth - 1),
            gen_expr(r, depth - 1)
        ),
        3 => {
            let d = r.range_i64(1, 9);
            format!("({} / {})", gen_expr(r, depth - 1), d)
        }
        4 => {
            let d = r.range_i64(1, 9);
            format!("({} % {})", gen_expr(r, depth - 1), d)
        }
        n => {
            let op = ["+", "-", "*", "<<", ">>", "==", "!=", "<", "<=", ">", ">=", "&&", "||"]
                [(n as usize - 5) % 13];
            format!("({} {} {})", gen_expr(r, depth - 1), op, gen_expr(r, depth - 1))
        }
    }
}

/// Evaluate `expr` by running `r = expr` one step on an engine.
fn eval_on<M: TransitionSystem>(m: &M) -> i64 {
    let init = m.initial_states().pop().unwrap();
    let mut out = Vec::new();
    m.successors(&init, &mut out);
    assert_eq!(out.len(), 1, "single deterministic assignment step");
    m.eval_var(&out[0], "r").unwrap()
}

#[test]
fn prop_bytecode_evaluation_matches_tree_walk() {
    forall(
        "promela-vm-expr-equivalence",
        Config { cases: 96, ..Config::default() },
        |r| {
            let g0 = r.range_i64(-100, 100);
            let g1 = r.range_i64(-100, 100);
            (g0, g1, gen_expr(r, 4))
        },
        |(g0, g1, expr)| {
            let src = format!(
                "int g0 = {}; int g1 = {}; int r;\nactive proctype main() {{ r = {} }}",
                g0, g1, expr
            );
            let interp = PromelaSystem::from_source(&src).map_err(|e| e.to_string())?;
            let vm = PromelaVm::from_source(&src).map_err(|e| e.to_string())?;
            let vi = eval_on(&interp);
            let vv = eval_on(&vm);
            prop_assert_eq!(vi, vv);
            Ok(())
        },
    );
}

// ------------------------------------------------- shard specialization --

/// The acceptance-criteria test: on a ≥4-shard Promela batch, the
/// specialized path produces byte-identical cache output and identical
/// deterministic report fields to the generic re-filtering path, while
/// generating strictly fewer raw successors.
#[test]
fn specialized_shards_match_refilter_byte_for_byte_and_generate_fewer() {
    let mut job = TuningJob::new(ModelKind::Minimum, 16);
    job.engine = JobEngine::Promela;
    job.plat.np = 2;
    job.plat.gmt = 1;
    job.shards = 6; // 6 requested -> 4 non-empty cells on the 16-lattice
    let jobs = vec![job];
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };

    let dir = std::env::temp_dir().join(format!("mcat_vmdiff_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ref_path = dir.join("ref_cache.json");
    let new_path = dir.join("new_cache.json");

    // Reference path: unspecialized VM behind the generic ShardModel
    // re-filter, folded through the same plan and merge as run_batch.
    let mut ref_cache = ResultCache::open(&ref_path).unwrap();
    let plan = plan_batch(&jobs, &opts, &mut ref_cache).unwrap();
    assert!(plan.tasks.len() >= 4, "need a >=4-shard batch, got {}", plan.tasks.len());
    let mut refilter_generated = 0u64;
    let mut ref_parts = Vec::new();
    for (ji, sp) in &plan.tasks {
        assert_eq!(*ji, 0);
        let JobModel::Pml(m) = jobs[0].build().unwrap() else {
            panic!("promela job builds a Pml model")
        };
        let vm = PromelaVm::new(m.prog).unwrap();
        let sm = ShardModel::new(&vm, sp.shard);
        let r = tune(&sm, Method::Exhaustive, &sp.check, &opts.swarm, Some(sp.t_ini)).unwrap();
        refilter_generated += vm.generated();
        ref_parts.push(r);
    }
    let ref_shard_stats: Vec<(u64, u32, u32, i64)> = ref_parts
        .iter()
        .map(|r| (r.states_explored, r.optimal.wg, r.optimal.ts, r.t_min))
        .collect();
    let merged = merge_results(ref_parts).unwrap();
    {
        use mcautotune::tuner::TuneCache;
        ref_cache.store(&plan.descs[0], &merged);
    }
    ref_cache.save().unwrap();

    // Production path: run_batch compiles one specialized program per shard.
    let mut new_cache = ResultCache::open(&new_path).unwrap();
    let report = run_batch(&jobs, &opts, &mut new_cache).unwrap();

    // (1) byte-identical cache output
    let ref_bytes = std::fs::read_to_string(&ref_path).unwrap();
    let new_bytes = std::fs::read_to_string(&new_path).unwrap();
    assert_eq!(ref_bytes, new_bytes, "cache files must be byte-identical");

    // (2) identical deterministic report fields
    let o = &report.outcomes[0];
    assert_eq!(
        (o.result.optimal.wg, o.result.optimal.ts, o.result.t_min),
        (merged.optimal.wg, merged.optimal.ts, merged.t_min)
    );
    assert_eq!(o.result.states_explored, merged.states_explored);
    assert_eq!(o.result.optimal.steps, merged.optimal.steps);

    // (3) per-shard equivalence + strictly fewer raw successors
    let mut specialized_generated = 0u64;
    for ((_, sp), want) in plan.tasks.iter().zip(&ref_shard_stats) {
        let JobModel::Pml(m) = jobs[0].build().unwrap() else {
            panic!("promela job builds a Pml model")
        };
        let vm = PromelaVm::specialized(m.prog, Some(sp.shard.promela_bounds())).unwrap();
        assert!(vm.is_specialized(), "sub-lattice bounds must be baked in");
        let r = tune(&vm, Method::Exhaustive, &sp.check, &opts.swarm, Some(sp.t_ini)).unwrap();
        specialized_generated += vm.generated();
        assert_eq!(
            (r.states_explored, r.optimal.wg, r.optimal.ts, r.t_min),
            *want,
            "specialized shard result must match the re-filtering path"
        );
    }
    assert!(
        specialized_generated < refilter_generated,
        "specialization must generate strictly fewer raw successors ({} vs {})",
        specialized_generated,
        refilter_generated
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Verdict/optimum equivalence of the three execution paths on an
/// unsharded job: interpreter, VM, and VM behind a full-lattice wrapper.
#[test]
fn tuner_finds_the_same_optimum_on_both_engines() {
    let src = templates::minimum_pml(8, 4, 3);
    let interp = PromelaSystem::from_source(&src).unwrap();
    let vm = PromelaVm::from_source(&src).unwrap();
    let opts = CheckOptions::default();
    let swarm = mcautotune::swarm::SwarmConfig::default();
    let ri = tune(&interp, Method::Exhaustive, &opts, &swarm, Some(10_000)).unwrap();
    let rv = tune(&vm, Method::Exhaustive, &opts, &swarm, Some(10_000)).unwrap();
    assert_eq!(ri.t_min, rv.t_min);
    assert_eq!((ri.optimal.wg, ri.optimal.ts), (rv.optimal.wg, rv.optimal.ts));
    assert_eq!(ri.states_explored, rv.states_explored);
    assert_eq!(ri.optimal.steps, rv.optimal.steps);
}
