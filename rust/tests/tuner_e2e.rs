//! End-to-end tuner behaviour: bisection iteration structure, swarm-search
//! stopping criterion, and the report drivers.

use mcautotune::checker::CheckOptions;
use mcautotune::platform::{AbstractModel, Granularity, MinModel, PlatformConfig};
use mcautotune::report::{table1, table3, Table1Opts};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{bisection, swarm_search};
use std::time::Duration;

#[test]
fn bisection_iteration_count_is_logarithmic() {
    let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Phase).unwrap();
    let r = bisection(&m, &CheckOptions::default(), 1 << 20).unwrap();
    // ~log2(range) + establishment calls; far fewer than linear scan
    assert!(r.iterations.len() <= 40, "got {} iterations", r.iterations.len());
    // every iteration with cex carries T >= t_min; every 'proved' < t_min
    for it in &r.iterations {
        if it.cex_found {
            assert!(it.t >= r.t_min, "cex at T={} below t_min={}", it.t, r.t_min);
        } else {
            assert!(it.t < r.t_min, "proved at T={} not below t_min={}", it.t, r.t_min);
        }
    }
}

#[test]
fn bisection_invariant_under_t_ini_choice() {
    let m = MinModel::paper(64, 4).unwrap();
    let r1 = bisection(&m, &CheckOptions::default(), 50).unwrap();
    let r2 = bisection(&m, &CheckOptions::default(), 5_000).unwrap();
    let r3 = bisection(&m, &CheckOptions::default(), 1).unwrap();
    assert_eq!(r1.t_min, r2.t_min);
    assert_eq!(r2.t_min, r3.t_min);
}

#[test]
fn swarm_search_stops_after_unproductive_round() {
    let m = MinModel::paper(64, 4).unwrap();
    let cfg = SwarmConfig {
        workers: 2,
        time_budget: Duration::from_secs(5),
        ..Default::default()
    };
    let r = swarm_search(&m, &cfg).unwrap();
    // final round must have found nothing better (that's why it stopped)
    let last = r.iterations.last().unwrap();
    assert!(
        last.best_time.is_none() || last.best_time.unwrap() >= r.t_min,
        "search stopped while still improving"
    );
}

#[test]
fn table1_rows_internally_consistent() {
    let opts = Table1Opts {
        sizes: vec![8, 16, 32],
        max_promela_size: 0,
        max_exhaustive_size: 32,
        swarm: SwarmConfig {
            workers: 2,
            time_budget: Duration::from_millis(400),
            ..Default::default()
        },
        ..Default::default()
    };
    let (rows, _) = table1(&opts).unwrap();
    assert_eq!(rows.len(), 3);
    for r in &rows {
        assert!(r.model_time > 0);
        assert!(r.optimality > 0.0 && r.optimality <= 1.0);
        assert!(r.wg.is_power_of_two() && r.ts.is_power_of_two());
        assert!(r.mem_swarm > 0);
    }
    // larger input → larger optimal model time (monotone workload)
    assert!(rows[0].model_time < rows[1].model_time);
    assert!(rows[1].model_time < rows[2].model_time);
}

#[test]
fn table3_reproduces_paper_shape() {
    // WG dominates TS: within each group the best row never has the
    // smallest WG available unless it is forced (paper §7.3)
    let (rows, _) = table3(&[(64, 128), (64, 256)], 3, 3).unwrap();
    for g in rows.chunks(3) {
        assert!(g[0].model_time <= g[1].model_time);
        assert!(g[1].model_time <= g[2].model_time);
        // the best configuration uses at least 4 PEs worth of WG
        assert!(g[0].wg >= 4, "best WG {} suspiciously small", g[0].wg);
    }
}
