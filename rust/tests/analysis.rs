//! Static-analysis suite: effect sets, liveness, lint diagnostics, and
//! the two opt-in reductions the analysis feeds.
//!
//! The reduction tests are differential against the unreduced engines on
//! the same corpus the VM conformance suite uses: `--reduce dead-slots`
//! and `--por` must preserve verdicts (and tuning optima) everywhere,
//! `states_stored` may only shrink, and pinned models must show a
//! *strict* drop so the reductions can never silently degrade to no-ops.

use mcautotune::checker::{check, CheckOptions, Frontier};
use mcautotune::coordinator::{JobEngine, ModelKind, TuningJob};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::platform::PlatformConfig;
use mcautotune::promela::analysis::{
    diagnostics, independent, lint_json, op_effects, require_tunable, validate_lint_json,
    Analysis, Severity,
};
use mcautotune::promela::compile::{
    CExpr, CLVal, Instr, Op, ProcDef, Program, Slot, VarInfo, VarType, NO_PC,
};
use mcautotune::promela::{templates, PromelaSystem, PromelaVm};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};
use mcautotune::util::manifest::Json;
use std::collections::HashMap;

/// Same corpus as the VM conformance suite (`tests/promela_vm.rs`): every
/// semantic feature of the subset plus the paper's two generated models.
fn corpus() -> Vec<(&'static str, String, &'static str)> {
    vec![
        (
            "seq-assign",
            "int a; int b; active proctype main() { a = 2; b = a + 3 }".into(),
            "G(true)",
        ),
        (
            "select",
            "int x; byte i; active proctype main() { select (i : 1 .. 3); x = i * 10 }".into(),
            "G(x != 20)",
        ),
        (
            "do-break",
            "int i; active proctype main() { do :: i < 5 -> i++ :: else -> break od }".into(),
            "G(i < 5)",
        ),
        (
            "arrays",
            "int a[4]; int s; byte i; active proctype main() {\
               for (i : 0 .. 3) { a[i] = i * i }\
               for (i : 0 .. 3) { s = s + a[i] } }"
                .into(),
            "G(s != 14)",
        ),
        (
            "rendezvous",
            "mtype = {go, done};\nchan c = [0] of {mtype};\nint got;\n\
             active proctype main() { run w(); c ! go; c ? done }\n\
             proctype w() { c ? go; got = 1; c ! done }"
                .into(),
            "G(got == 0)",
        ),
        (
            "rendezvous-match",
            "mtype = {go, stop};\nchan c = [0] of {mtype};\nint path;\n\
             active proctype main() { run w(); c ! go }\n\
             proctype w() { if :: c ? go -> path = 1 :: c ? stop -> path = 2 fi }"
                .into(),
            "G(path == 0)",
        ),
        (
            "buffered-fifo",
            "chan c = [2] of {byte};\nint a; int b;\n\
             active proctype main() { c ! 1; c ! 2; run w() }\n\
             proctype w() { byte x; c ? x; a = x; c ? x; b = x }"
                .into(),
            "G(b != 2)",
        ),
        (
            "else-choice",
            "int x = 1; int r;\n\
             active proctype main() { if :: x == 1 -> r = 10 :: else -> r = 20 fi }"
                .into(),
            "G(true)",
        ),
        (
            "interleave-race",
            "int x;\nactive proctype main() { run a(); run b() }\n\
             proctype a() { x = 1 }\nproctype b() { x = 2 }"
                .into(),
            "G(x != 2)",
        ),
        (
            "atomic-increment",
            "int x;\nactive proctype main() { run a(); run b() }\n\
             proctype a() { int t; atomic { t = x; x = t + 1 } }\n\
             proctype b() { int t; atomic { t = x; x = t + 1 } }"
                .into(),
            "G(x != 2)",
        ),
        (
            "blocking-guard",
            "int flag; int r;\n\
             active proctype main() { run setter(); flag == 1; r = 99 }\n\
             proctype setter() { flag = 1 }"
                .into(),
            "G(r != 99)",
        ),
        (
            "deadlock",
            "chan c = [0] of {byte};\nint r;\nactive proctype main() { byte x; c ? x; r = 1 }"
                .into(),
            "G(true)",
        ),
        (
            "local-chan",
            "int got;\n\
             active proctype main() { chan c = [1] of {byte}; c ! 9; byte x; c ? x; got = x }"
                .into(),
            "G(got != 9)",
        ),
        (
            "byte-wrap",
            "byte k = 200; int laps;\n\
             active proctype main() { do :: k != 0 -> k++ :: else -> break od; laps = 1 }"
                .into(),
            "G(!(k == 0 && laps == 1))",
        ),
        (
            "clock-mini",
            r#"
            int time; int nrp; int active_n = 2; bool FIN;
            active proctype main() { atomic { run p(); run p(); run clock() } }
            proctype p() {
              byte k; int cur;
              for (k : 0 .. 2) {
                atomic { cur = time; nrp = nrp + 1 };
                time > cur
              };
              atomic { active_n = active_n - 1; FIN = (active_n == 0 -> 1 : 0) }
            }
            proctype clock() {
              do
              :: FIN -> break
              :: !FIN && nrp >= active_n && active_n > 0 ->
                   atomic { nrp = 0; time = time + 1 }
              od
            }
            "#
            .into(),
            "G(FIN -> time > 3)",
        ),
        ("minimum-8", templates::minimum_pml(8, 4, 3), "G(!FIN)"),
        (
            "abstract-8",
            templates::abstract_pml(8, &PlatformConfig { nd: 1, nu: 1, np: 2, gmt: 2 }),
            "G(!FIN)",
        ),
    ]
}

// -------------------------------------------------------- effect sets --

#[test]
fn effect_sets_follow_the_op_syntax() {
    let e = op_effects(&Op::Guard(CExpr::Load(Slot::Global(3))));
    assert!(e.global_reads.contains(3));
    assert!(e.global_writes.is_empty() && e.local_writes.is_empty());

    // scalar local assign: strong kill; rhs reads both scopes
    let e = op_effects(&Op::Assign(
        CLVal::Scalar(Slot::Local(2), VarType::Int),
        CExpr::Bin(
            mcautotune::promela::ast::PBinOp::Add,
            Box::new(CExpr::Load(Slot::Local(1))),
            Box::new(CExpr::Load(Slot::Global(0))),
        ),
    ));
    assert!(e.local_reads.contains(1) && e.global_reads.contains(0));
    assert!(e.local_writes.contains(2) && e.local_kills.contains(2));

    // constant in-range element index: a single-cell strong kill
    let e = op_effects(&Op::Assign(
        CLVal::Elem(Slot::Local(4), 3, CExpr::Num(1), VarType::Int),
        CExpr::Num(0),
    ));
    assert!(e.local_writes.contains(5) && e.local_kills.contains(5));
    assert!(!e.local_writes.contains(4) && !e.local_writes.contains(6));

    // dynamic index: weak write of the whole range, no kills
    let e = op_effects(&Op::Assign(
        CLVal::Elem(Slot::Local(4), 3, CExpr::Load(Slot::Local(0)), VarType::Int),
        CExpr::Num(0),
    ));
    assert!(e.local_reads.contains(0));
    assert!((4..7).all(|s| e.local_writes.contains(s)));
    assert!(e.local_kills.is_empty());

    // static vs dynamic channel handles
    let e = op_effects(&Op::Send(CExpr::Num(2), vec![CExpr::Load(Slot::Global(1))]));
    assert!(e.chans.contains(2) && !e.chan_dynamic && e.global_reads.contains(1));
    let e = op_effects(&Op::Send(CExpr::Load(Slot::Local(0)), vec![]));
    assert!(e.chan_dynamic && e.local_reads.contains(0));

    // structural effects
    assert!(op_effects(&Op::Run(0, vec![])).spawns);
    assert!(op_effects(&Op::Halt).halts);
    let e = op_effects(&Op::NewChan(CLVal::Scalar(Slot::Local(0), VarType::Int), 1, 1));
    assert!(e.allocs && e.local_writes.contains(0));
}

#[test]
fn independence_is_global_footprint_disjointness() {
    let local_a = op_effects(&Op::Assign(
        CLVal::Scalar(Slot::Local(0), VarType::Int),
        CExpr::Num(1),
    ));
    let local_b = op_effects(&Op::Assign(
        CLVal::Scalar(Slot::Local(3), VarType::Int),
        CExpr::Load(Slot::Local(2)),
    ));
    assert!(independent(&local_a, &local_b), "local-only ops are independent");

    let wg0 = op_effects(&Op::Assign(
        CLVal::Scalar(Slot::Global(0), VarType::Int),
        CExpr::Num(1),
    ));
    let rg0 = op_effects(&Op::Guard(CExpr::Load(Slot::Global(0))));
    let wg1 = op_effects(&Op::Assign(
        CLVal::Scalar(Slot::Global(1), VarType::Int),
        CExpr::Num(1),
    ));
    assert!(!independent(&wg0, &rg0), "write/read of the same global conflicts");
    assert!(!independent(&wg0, &wg0), "write/write conflicts");
    assert!(independent(&wg0, &wg1), "disjoint globals commute");

    let send1 = op_effects(&Op::Send(CExpr::Num(1), vec![]));
    let recv1 = op_effects(&Op::Recv(CExpr::Num(1), vec![]));
    let send2 = op_effects(&Op::Send(CExpr::Num(2), vec![]));
    assert!(!independent(&send1, &recv1), "same channel conflicts");
    assert!(independent(&send2, &recv1), "distinct channels commute");
    assert!(!independent(&local_a, &op_effects(&Op::Run(0, vec![]))), "spawns never commute");
}

// ----------------------------------------------- liveness on automata --

fn instr(op: Op, next: u32) -> Instr {
    Instr { op, next, atomic_next: false }
}

/// Hand-built single-proc program: `t = 1; t = 2; g = t; halt` — the
/// first store to `t` is provably dead.
fn tiny_prog() -> Program {
    let code = vec![
        instr(Op::Assign(CLVal::Scalar(Slot::Local(0), VarType::Int), CExpr::Num(1)), 1),
        instr(Op::Assign(CLVal::Scalar(Slot::Local(0), VarType::Int), CExpr::Num(2)), 2),
        instr(
            Op::Assign(
                CLVal::Scalar(Slot::Global(0), VarType::Int),
                CExpr::Load(Slot::Local(0)),
            ),
            3,
        ),
        instr(Op::Halt, NO_PC),
    ];
    let mut global_syms = HashMap::new();
    global_syms.insert("g".to_string(), VarInfo { offset: 0, len: 1, ty: VarType::Int });
    Program {
        mtypes: vec![],
        global_syms,
        globals_init: vec![0],
        global_chans: vec![],
        procs: vec![ProcDef {
            name: "main".into(),
            nparams: 0,
            param_types: vec![],
            nlocals: 1,
            code,
            entry: 0,
            locals: vec![("t".into(), VarInfo { offset: 0, len: 1, ty: VarType::Int })],
        }],
        active: vec![0],
    }
}

#[test]
fn liveness_fixpoint_proves_the_dead_store() {
    let prog = tiny_prog();
    let a = Analysis::of(&prog);
    // `t` is dead entering both stores (each is overwritten before a read)
    assert!(a.slot_dead_at(0, 0, 0), "t dead entering `t = 1`");
    assert!(a.slot_dead_at(0, 1, 0), "t dead entering `t = 2`");
    assert!(a.live_at(0, 2).contains(0), "t live entering `g = t`");
    // POR: the two local stores are ample-eligible, the global write is not
    assert!(a.por_safe(0, 0) && a.por_safe(0, 1));
    assert!(!a.por_safe(0, 2), "global write is visible");
    assert!(!a.por_safe(0, 3), "Halt as the resting op is never ample");

    let diags = diagnostics(&prog);
    let dead: Vec<_> = diags.iter().filter(|d| d.category == "dead-store").collect();
    assert_eq!(dead.len(), 1, "exactly the first store is dead: {:?}", diags);
    assert_eq!(dead[0].pc, Some(0));
    assert!(dead[0].message.contains('t'), "names the source local: {}", dead[0].message);
    assert!(
        diags.iter().any(|d| d.category == "global-write-only" && d.severity == Severity::Info),
        "write-only `g` is an info, not a warning"
    );
}

// --------------------------------------------------------- lint gate --

#[test]
fn generated_templates_are_lint_clean() {
    for (name, src) in [
        ("minimum", templates::minimum_pml(8, 4, 3)),
        ("abstract", templates::abstract_pml(8, &PlatformConfig { nd: 1, nu: 1, np: 2, gmt: 2 })),
    ] {
        let sys = PromelaSystem::from_source(&src).unwrap();
        let warns: Vec<_> = diagnostics(&sys.prog)
            .into_iter()
            .filter(|d| d.severity == Severity::Warn)
            .collect();
        assert!(warns.is_empty(), "{} template must pass `lint --deny`: {:?}", name, warns);
    }
}

#[test]
fn dirty_model_fires_the_expected_categories() {
    let src = "int WG; int TS; int unused_g;\n\
               chan c = [2] of {byte};\n\
               active proctype main() { int t; if :: 0 -> t = 1 :: else -> t = 2 fi }";
    let sys = PromelaSystem::from_source(src).unwrap();
    let diags = diagnostics(&sys.prog);
    for want in
        ["tuning-unassigned", "global-unused", "chan-never-sent", "local-unused", "guard-false"]
    {
        assert!(
            diags.iter().any(|d| d.category == want),
            "expected a `{}` diagnostic, got {:?}",
            want,
            diags
        );
    }
    assert!(
        diags.iter().filter(|d| d.category == "tuning-unassigned").count() == 2,
        "both WG and TS are unassigned"
    );
}

#[test]
fn lint_json_satisfies_and_enforces_its_schema() {
    let src = "int WG; int TS;\nactive proctype main() { int t; t = 1 }";
    let sys = PromelaSystem::from_source(src).unwrap();
    let diags = diagnostics(&sys.prog);
    let j = lint_json("dirty.pml", &sys.prog, &diags);
    validate_lint_json(&j).expect("emitted report must satisfy its own schema");
    // the document round-trips through the JSON text layer
    let parsed = Json::parse(&j.render()).unwrap();
    validate_lint_json(&parsed).unwrap();

    // tampering with the summary counts must be rejected
    let Json::Obj(fields) = &j else { panic!("lint doc is an object") };
    let tampered: Vec<(String, Json)> = fields
        .iter()
        .map(|(k, v)| {
            if k == "summary" {
                (k.clone(), Json::Obj(vec![
                    ("warns".to_string(), Json::Int(99)),
                    ("infos".to_string(), Json::Int(0)),
                ]))
            } else {
                (k.clone(), v.clone())
            }
        })
        .collect();
    assert!(validate_lint_json(&Json::Obj(tampered)).is_err(), "bad summary must fail");
    assert!(
        validate_lint_json(&Json::Obj(vec![(
            "tool".to_string(),
            Json::Str("not-lint".into())
        )]))
        .is_err(),
        "wrong tool tag must fail"
    );
}

// ------------------------------------------- degenerate-lattice guard --

#[test]
fn untunable_sources_error_before_any_search() {
    // never assigned and zero-initialized: degenerate lattice
    let mut job = TuningJob::new(ModelKind::Minimum, 8);
    job.engine = JobEngine::Promela;
    job.source =
        Some("int WG; int TS; bool FIN;\nactive proctype main() { FIN = 1 }".into());
    let err = job.build().unwrap_err().to_string();
    assert!(err.contains("never assigned"), "plan-time error names the cause: {}", err);
    assert!(err.contains("lint"), "error points at the lint command: {}", err);

    // not declared at all
    job.source = Some("bool FIN;\nactive proctype main() { FIN = 1 }".into());
    let err = job.build().unwrap_err().to_string();
    assert!(err.contains("not declared"), "{}", err);

    // positive initializers count as assignment (preset-tuning sources)
    job.source =
        Some("int WG = 2; int TS = 2; bool FIN;\nactive proctype main() { FIN = 1 }".into());
    job.build().expect("initialized tuning slots form a valid lattice");

    // the generated templates assign WG/TS via the tuner choice points
    let sys = PromelaSystem::from_source(&templates::minimum_pml(8, 4, 3)).unwrap();
    require_tunable(&sys.prog).unwrap();
}

// ------------------------------------------------ reduction: verdicts --

fn opts_dfs() -> CheckOptions {
    CheckOptions { collect_all: true, ..CheckOptions::default() }
}

fn opts_det4() -> CheckOptions {
    CheckOptions {
        collect_all: true,
        threads: 4,
        frontier: Frontier::Deterministic,
        ..CheckOptions::default()
    }
}

#[test]
fn dead_slot_reduction_preserves_verdicts_on_the_full_corpus() {
    for (name, src, prop) in corpus() {
        let prop = SafetyLtl::parse(prop).unwrap();
        let base_i = PromelaSystem::from_source(&src).unwrap();
        let base_v = PromelaVm::from_source(&src).unwrap();
        let red_i = PromelaSystem::from_source(&src).unwrap().with_dead_slot_reduction();
        let red_v = PromelaVm::from_source(&src).unwrap().with_dead_slot_reduction();
        for (label, opts) in [("dfs", opts_dfs()), ("det4", opts_det4())] {
            let bi = check(&base_i, &prop, &opts).unwrap();
            let bv = check(&base_v, &prop, &opts).unwrap();
            let ri = check(&red_i, &prop, &opts).unwrap();
            let rv = check(&red_v, &prop, &opts).unwrap();
            assert_eq!(bi.found(), ri.found(), "{}/{}: interp verdict", name, label);
            assert_eq!(bv.found(), rv.found(), "{}/{}: vm verdict", name, label);
            assert_eq!(bi.exhausted, ri.exhausted, "{}/{}: interp exhausted", name, label);
            assert_eq!(bv.exhausted, rv.exhausted, "{}/{}: vm exhausted", name, label);
            assert!(
                ri.stats.states_stored <= bi.stats.states_stored,
                "{}/{}: reduction may only shrink the store ({} > {})",
                name, label, ri.stats.states_stored, bi.stats.states_stored
            );
            assert_eq!(
                ri.stats.states_stored, rv.stats.states_stored,
                "{}/{}: both reduced engines store the same count",
                name, label
            );
        }
    }
}

#[test]
fn por_preserves_verdicts_on_the_full_corpus() {
    // the validated scope of `--por`: the two deterministic engines — the
    // sequential DFS and the depth-synchronous parallel frontier. The
    // reduced graph is a pure function of the state (ample selection
    // reads only the state), so both must store the same count.
    for (label, base) in [("dfs", opts_dfs()), ("det4", opts_det4())] {
        let por = CheckOptions { por: true, ..base.clone() };
        for (name, src, prop) in corpus() {
            let prop = SafetyLtl::parse(prop).unwrap();
            let interp = PromelaSystem::from_source(&src).unwrap();
            let vm = PromelaVm::from_source(&src).unwrap();
            let bi = check(&interp, &prop, &base).unwrap();
            let pi = check(&interp, &prop, &por).unwrap();
            let bv = check(&vm, &prop, &base).unwrap();
            let pv = check(&vm, &prop, &por).unwrap();
            assert_eq!(bi.found(), pi.found(), "{}/{}: interp verdict under por", name, label);
            assert_eq!(bv.found(), pv.found(), "{}/{}: vm verdict under por", name, label);
            assert_eq!(bi.exhausted, pi.exhausted, "{}/{}: interp exhausted", name, label);
            assert_eq!(bv.exhausted, pv.exhausted, "{}/{}: vm exhausted", name, label);
            assert!(
                pi.stats.states_stored <= bi.stats.states_stored,
                "{}/{}: por may only shrink the store ({} > {})",
                name, label, pi.stats.states_stored, bi.stats.states_stored
            );
            assert_eq!(
                pi.stats.states_stored, pv.stats.states_stored,
                "{}/{}: both reduced engines store the same count",
                name, label
            );
        }
    }
}

/// `--por --reduce dead-slots` compose: ample selection reads pcs,
/// liveness and enabledness from the *raw* state, while dead-slot
/// canonicalization rewrites only the hashed image in `encode` — the
/// two reductions touch disjoint machinery, and composing them must
/// keep every verdict while storing no more states than either alone.
#[test]
fn por_composes_with_dead_slot_reduction_on_the_full_corpus() {
    for (label, base) in [("dfs", opts_dfs()), ("det4", opts_det4())] {
        let por = CheckOptions { por: true, ..base.clone() };
        for (name, src, prop) in corpus() {
            let prop = SafetyLtl::parse(prop).unwrap();
            let plain_v = PromelaVm::from_source(&src).unwrap();
            let both_v = PromelaVm::from_source(&src).unwrap().with_dead_slot_reduction();
            let both_i = PromelaSystem::from_source(&src).unwrap().with_dead_slot_reduction();
            let b = check(&plain_v, &prop, &base).unwrap();
            let cv = check(&both_v, &prop, &por).unwrap();
            let ci = check(&both_i, &prop, &por).unwrap();
            assert_eq!(b.found(), cv.found(), "{}/{}: verdict under por+dead-slots", name, label);
            assert_eq!(b.exhausted, cv.exhausted, "{}/{}: exhausted", name, label);
            assert!(
                cv.stats.states_stored <= b.stats.states_stored,
                "{}/{}: combined reduction may only shrink ({} > {})",
                name, label, cv.stats.states_stored, b.stats.states_stored
            );
            assert_eq!(
                cv.stats.states_stored, ci.stats.states_stored,
                "{}/{}: both engines agree under the combined reduction",
                name, label
            );
        }
    }
}

// ------------------------------------------- channel-aware ample sets --

/// Straight-line exclusive producer/consumer over a buffered channel:
/// the sends and receives are local-only channel ops, so the
/// channel-aware eligibility rule makes them singleton ample sets.
const CHAN_POR_SRC: &str = "chan c = [2] of {byte};\nint got;\n\
     active proctype prod() { c ! 1; c ! 2 }\n\
     active proctype cons() { byte x; c ? x; c ? x; got = x }";

#[test]
fn exclusive_channel_roles_feed_ample_eligibility() {
    let sys = PromelaSystem::from_source(CHAN_POR_SRC).unwrap();
    let a = Analysis::of(&sys.prog);
    // prod is ptype 0, cons ptype 1; channel 0 has one static site each
    assert_eq!(a.exclusive_sender(0), Some(0));
    assert_eq!(a.exclusive_recver(0), Some(1));
    // the sends and the first recv are ample-eligible at their pcs; the
    // final recv chain ends in a global write, but the recv itself is
    // still a local-only channel op
    assert!(a.por_safe(0, 0), "prod's first send is ample");
    assert!(a.por_safe(1, 0), "cons's first recv is ample");

    // two senders on one channel: sender exclusivity dissolves
    let two = PromelaSystem::from_source(
        "chan c = [2] of {byte};\n\
         active proctype a() { c ! 1 }\nactive proctype b() { c ! 2 }\n\
         active proctype r() { byte x; c ? x; c ? x }",
    )
    .unwrap();
    let a2 = Analysis::of(&two.prog);
    assert_eq!(a2.exclusive_sender(0), None, "two senders poison the role");
    assert_eq!(a2.exclusive_recver(0), Some(2));
    assert!(!a2.por_safe(0, 0), "non-exclusive send is not ample");

    // rendezvous (cap 0) is excluded regardless of exclusivity
    let rv = PromelaSystem::from_source(
        "chan c = [0] of {byte};\n\
         active proctype s() { c ! 1 }\nactive proctype r() { byte x; c ? x }",
    )
    .unwrap();
    let a3 = Analysis::of(&rv.prog);
    assert!(!a3.por_safe(0, 0), "rendezvous send is never ample");
    assert!(!a3.por_safe(1, 0), "rendezvous recv is never ample");
}

#[test]
fn channel_por_strictly_reduces_and_preserves_the_verdict() {
    let prop = SafetyLtl::parse("G(got != 2)").unwrap();
    for (label, base) in [("dfs", opts_dfs()), ("det4", opts_det4())] {
        let por = CheckOptions { por: true, ..base.clone() };
        let b = check(&PromelaVm::from_source(CHAN_POR_SRC).unwrap(), &prop, &base).unwrap();
        let p = check(&PromelaVm::from_source(CHAN_POR_SRC).unwrap(), &prop, &por).unwrap();
        assert_eq!(b.found(), p.found(), "{}: verdict preserved", label);
        assert!(b.found(), "{}: the final recv does commit got=2", label);
        assert_eq!(b.exhausted, p.exhausted, "{}: exhausted", label);
        assert!(
            p.stats.states_stored < b.stats.states_stored,
            "{}: channel-aware por must strictly reduce ({} vs {})",
            label, p.stats.states_stored, b.stats.states_stored
        );
        let pi =
            check(&PromelaSystem::from_source(CHAN_POR_SRC).unwrap(), &prop, &por).unwrap();
        assert_eq!(pi.stats.states_stored, p.stats.states_stored, "{}: engines agree", label);
    }
}

/// Anti-no-op pins: at least these corpus models must show a *strict*
/// drop, so a regression that silently disables either reduction fails.
#[test]
fn pinned_models_show_strict_state_reduction() {
    // dead-slots: the two `t` copies of atomic-increment die after their
    // atomic blocks, collapsing symmetric final states
    let src = "int x;\nactive proctype main() { run a(); run b() }\n\
               proctype a() { int t; atomic { t = x; x = t + 1 } }\n\
               proctype b() { int t; atomic { t = x; x = t + 1 } }";
    let prop = SafetyLtl::parse("G(x != 2)").unwrap();
    let base = check(&PromelaVm::from_source(src).unwrap(), &prop, &opts_dfs()).unwrap();
    let red = check(
        &PromelaVm::from_source(src).unwrap().with_dead_slot_reduction(),
        &prop,
        &opts_dfs(),
    )
    .unwrap();
    assert!(
        red.stats.states_stored < base.stats.states_stored,
        "dead-slots must strictly reduce atomic-increment ({} vs {})",
        red.stats.states_stored,
        base.stats.states_stored
    );
    let redi = check(
        &PromelaSystem::from_source(src).unwrap().with_dead_slot_reduction(),
        &prop,
        &opts_dfs(),
    )
    .unwrap();
    assert_eq!(redi.stats.states_stored, red.stats.states_stored);

    // por: minimum-8 has local-only forward stretches (loop initializers)
    // that serve as singleton ample sets
    let src = templates::minimum_pml(8, 4, 3);
    let prop = SafetyLtl::parse("G(!FIN)").unwrap();
    let por = CheckOptions { por: true, ..opts_dfs() };
    let base = check(&PromelaVm::from_source(&src).unwrap(), &prop, &opts_dfs()).unwrap();
    let reduced = check(&PromelaVm::from_source(&src).unwrap(), &prop, &por).unwrap();
    assert!(
        reduced.stats.states_stored < base.stats.states_stored,
        "por must strictly reduce minimum-8 ({} vs {})",
        reduced.stats.states_stored,
        base.stats.states_stored
    );
    let reduced_i = check(&PromelaSystem::from_source(&src).unwrap(), &prop, &por).unwrap();
    assert_eq!(reduced_i.stats.states_stored, reduced.stats.states_stored);
}

// -------------------------------------------------- reduction: optima --

#[test]
fn reductions_preserve_the_tuning_optimum() {
    let src = templates::minimum_pml(8, 4, 3);
    let swarm = SwarmConfig::default();
    let plain = CheckOptions::default();
    let por = CheckOptions { por: true, ..CheckOptions::default() };

    let base = tune(
        &PromelaVm::from_source(&src).unwrap(),
        Method::Exhaustive,
        &plain,
        &swarm,
        Some(10_000),
    )
    .unwrap();
    let want = (base.optimal.wg, base.optimal.ts, base.t_min);

    for (label, model, opts) in [
        ("vm+por", PromelaVm::from_source(&src).unwrap(), &por),
        ("vm+dead-slots", PromelaVm::from_source(&src).unwrap().with_dead_slot_reduction(), &plain),
        (
            "vm+por+dead-slots",
            PromelaVm::from_source(&src).unwrap().with_dead_slot_reduction(),
            &por,
        ),
    ] {
        let r = tune(&model, Method::Exhaustive, opts, &swarm, Some(10_000)).unwrap();
        assert_eq!((r.optimal.wg, r.optimal.ts, r.t_min), want, "{}: optimum", label);
    }
    for (label, model, opts) in [
        ("interp+por", PromelaSystem::from_source(&src).unwrap(), &por),
        (
            "interp+dead-slots",
            PromelaSystem::from_source(&src).unwrap().with_dead_slot_reduction(),
            &plain,
        ),
    ] {
        let r = tune(&model, Method::Exhaustive, opts, &swarm, Some(10_000)).unwrap();
        assert_eq!((r.optimal.wg, r.optimal.ts, r.t_min), want, "{}: optimum", label);
    }
}

// ------------------------------------------------- default-path guard --

/// With the flag off the analysis is never consulted; with it on, states
/// whose dead slots are already zero must encode byte-identically — the
/// canonicalization only ever rewrites nonzero garbage.
#[test]
fn default_encodings_are_untouched_and_initial_states_are_canonical() {
    for (name, src, _) in corpus() {
        let base = PromelaVm::from_source(&src).unwrap();
        let red = PromelaVm::from_source(&src).unwrap().with_dead_slot_reduction();
        let s = base.initial_states().pop().unwrap();
        let sr = red.initial_states().pop().unwrap();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        base.encode(&s, &mut a);
        red.encode(&sr, &mut b);
        assert_eq!(a, b, "{}: initial-state locals start zeroed on both paths", name);

        let base = PromelaSystem::from_source(&src).unwrap();
        let red = PromelaSystem::from_source(&src).unwrap().with_dead_slot_reduction();
        let s = base.initial_states().pop().unwrap();
        let sr = red.initial_states().pop().unwrap();
        let (mut a, mut b) = (Vec::new(), Vec::new());
        base.encode(&s, &mut a);
        red.encode(&sr, &mut b);
        assert_eq!(a, b, "{}: interp initial-state encodings agree", name);
    }
}
