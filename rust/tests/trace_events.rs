//! Flight-recorder telemetry (`rust/src/obs`): JSONL schema roundtrips,
//! the determinism contract for `run`/`shard` events, worker-mode lease
//! events, and BrokenPipe-safe CLI output.
//!
//! Global-recorder runs are exercised through spawned `mcautotune`
//! processes (the recorder is process-global, so in-process tests would
//! race the threaded test runner); library-level tests use an explicit
//! in-memory [`Recorder`].

use mcautotune::coordinator::TaskDir;
use mcautotune::obs::{deterministic_lines, ju64, validate, Recorder};
use mcautotune::util::manifest::Json;
use std::path::PathBuf;
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicU32, Ordering};

const BIN: &str = env!("CARGO_BIN_EXE_mcautotune");

fn temp(tag: &str) -> PathBuf {
    static SEQ: AtomicU32 = AtomicU32::new(0);
    std::env::temp_dir().join(format!(
        "mcat_trace_{}_{}_{}",
        tag,
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn run_bin(args: &[&str]) -> String {
    let out = Command::new(BIN).args(args).output().expect("spawn mcautotune");
    assert!(
        out.status.success(),
        "mcautotune {:?} failed:\nstdout: {}\nstderr: {}",
        args,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn kind<'a>(e: &'a Json) -> Option<&'a str> {
    e.get("k").and_then(Json::as_str)
}

// ------------------------------------------------------- schema roundtrip --

#[test]
fn recorder_schema_roundtrips_spans_and_u64() {
    let r = Recorder::in_memory();
    r.event("meta", vec![("cmd", Json::Str("test".into()))]);
    let v = r.span("outer", || r.span("outer/inner", || 21) * 2);
    assert_eq!(v, 42);
    r.det_event(
        "run",
        vec![("cmd", Json::Str("test".into())), ("states", ju64(u64::MAX))],
    );
    r.finish().unwrap();
    let text = r.render();
    let events = validate(&text).unwrap();
    assert_eq!(events.len(), 5, "meta + two spans + run + counters:\n{}", text);

    // u64 beyond i64 roundtrips losslessly as a decimal string
    let run = events.iter().find(|e| kind(e) == Some("run")).unwrap();
    let s = run.get("states").and_then(Json::as_str).expect("decimal-string u64");
    assert_eq!(s.parse::<u64>().unwrap(), u64::MAX);

    // spans nest: the inner span completes (and appears) before the outer
    let spans: Vec<&str> = events
        .iter()
        .filter(|e| kind(e) == Some("span"))
        .map(|e| e.get("path").and_then(Json::as_str).unwrap())
        .collect();
    assert_eq!(spans, ["outer/inner", "outer"]);

    // only the run event is pinned deterministic
    assert_eq!(deterministic_lines(&text).len(), 1);
}

// --------------------------------------------------- determinism contract --

#[test]
fn det_verify_traces_are_byte_identical_across_runs() {
    let t1 = temp("det1");
    let t2 = temp("det2");
    for t in [&t1, &t2] {
        run_bin(&[
            "verify",
            "--model",
            "minimum",
            "--size",
            "16",
            "--frontier",
            "det",
            "--threads",
            "4",
            "--trace",
            t.to_str().unwrap(),
        ]);
    }
    let a = std::fs::read_to_string(&t1).unwrap();
    let b = std::fs::read_to_string(&t2).unwrap();
    validate(&a).unwrap();
    validate(&b).unwrap();
    let (da, db) = (deterministic_lines(&a), deterministic_lines(&b));
    assert!(!da.is_empty(), "verify must emit a `run` event:\n{}", a);
    assert_eq!(da, db, "deterministic event content must be byte-identical");
    assert!(da[0].contains("verify"), "run event names its command: {}", da[0]);
}

#[test]
fn worker_mode_shard_events_match_the_single_process_run() {
    let spec = "job minimum size=16 np=4 gmt=3 shards=2\n";
    let spec_path = temp("spec");
    std::fs::write(&spec_path, spec).unwrap();
    let spec_s = spec_path.to_str().unwrap();

    // single-process reference trace
    let single_trace = temp("single");
    run_bin(&[
        "batch",
        spec_s,
        "--cache",
        "none",
        "--frontier",
        "det",
        "--trace",
        single_trace.to_str().unwrap(),
    ]);

    // the same plan drained by two traced worker processes
    let dir = temp("tasks");
    let dir_s = dir.to_str().unwrap();
    run_bin(&[
        "batch", spec_s, "--task-dir", dir_s, "--plan-only", "--cache", "none",
        "--frontier", "det",
    ]);
    let w_traces = [temp("w0"), temp("w1")];
    let workers: Vec<_> = w_traces
        .iter()
        .map(|t| {
            Command::new(BIN)
                .args(["worker", dir_s, "--trace", t.to_str().unwrap()])
                .spawn()
                .expect("spawn worker")
        })
        .collect();
    for mut w in workers {
        assert!(w.wait().expect("worker wait").success(), "worker process failed");
    }

    let single = std::fs::read_to_string(&single_trace).unwrap();
    validate(&single).unwrap();
    let mut expect = deterministic_lines(&single);
    let mut got = Vec::new();
    let mut grants = 0;
    for t in &w_traces {
        let text = std::fs::read_to_string(t).unwrap();
        let events = validate(&text).unwrap();
        got.extend(deterministic_lines(&text));
        for e in events.iter().filter(|e| kind(e) == Some("lease")) {
            if e.get("action").and_then(Json::as_str) == Some("grant") {
                grants += 1;
                let owner = e.get("owner").and_then(Json::as_str).expect("lease owner");
                assert!(owner.contains('@'), "owner must be pid@host, got `{}`", owner);
            }
        }
    }
    assert_eq!(grants, 2, "each planned shard is leased exactly once");
    assert!(!expect.is_empty(), "the batch must emit shard events:\n{}", single);
    expect.sort();
    got.sort();
    assert_eq!(
        expect, got,
        "worker-mode shard events must be byte-identical to the single-process run"
    );

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&single_trace).ok();
    for t in &w_traces {
        std::fs::remove_file(t).ok();
    }
}

// ------------------------------------------------------ lease observability --

#[test]
fn recovery_worker_trace_records_reclaim_grant_and_heartbeat() {
    let spec_path = temp("spec");
    std::fs::write(&spec_path, "job minimum size=16 np=4 gmt=3 shards=1\n").unwrap();
    let dir = temp("tasks");
    let dir_s = dir.to_str().unwrap();
    run_bin(&[
        "batch", spec_path.to_str().unwrap(), "--task-dir", dir_s, "--plan-only",
        "--cache", "none",
    ]);

    // a worker leases the task and "crashes": the lease file stays behind
    let abandoned = TaskDir::new(&dir).lease().unwrap().expect("a task to abandon");
    drop(abandoned);

    // a traced recovery worker with a short TTL re-leases and finishes it
    let trace = temp("recovery");
    let out = run_bin(&[
        "worker", dir_s, "--ttl-ms", "300", "--poll-ms", "50", "--trace",
        trace.to_str().unwrap(),
    ]);
    assert!(out.contains("1 reclaimed"), "recovery must reclaim the stale lease: {}", out);

    let text = std::fs::read_to_string(&trace).unwrap();
    let events = validate(&text).unwrap();
    let actions: Vec<&str> = events
        .iter()
        .filter(|e| kind(e) == Some("lease"))
        .map(|e| e.get("action").and_then(Json::as_str).expect("lease action"))
        .collect();
    assert!(actions.contains(&"reclaim"), "reclaim event missing: {:?}\n{}", actions, text);
    assert!(actions.contains(&"grant"), "grant event missing: {:?}", actions);
    assert!(
        actions.contains(&"heartbeat"),
        "the execution-start heartbeat must appear even for short tasks: {:?}",
        actions
    );
    // the final counters dump mirrors the events
    let counters = events.iter().rev().find(|e| kind(e) == Some("counters")).unwrap();
    assert_eq!(counters.get("lease.reclaims").and_then(Json::as_i64), Some(1));

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&trace).ok();
}

// ------------------------------------------------------------ CLI plumbing --

#[test]
fn trace_subcommand_summarizes_a_recorded_run() {
    let t = temp("summary");
    run_bin(&["verify", "--model", "minimum", "--size", "8", "--trace", t.to_str().unwrap()]);
    let out = run_bin(&["trace", t.to_str().unwrap()]);
    assert!(out.contains("trace:"), "summary header missing:\n{}", out);
    assert!(out.contains("top spans"), "span table missing:\n{}", out);
    assert!(out.contains("counters:"), "counter dump missing:\n{}", out);
    assert!(out.contains("checker.states_stored"), "schema counter names missing:\n{}", out);
    std::fs::remove_file(&t).ok();
}

#[test]
fn closed_stdout_pipe_is_normal_termination() {
    // `| head` semantics: the reader going away must exit 0, not panic
    let mut child = Command::new(BIN)
        .arg("help")
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn mcautotune");
    drop(child.stdout.take()); // close the only read end immediately
    let status = child.wait().expect("wait");
    assert!(status.success(), "closed stdout must be a clean exit, got {:?}", status);
}
