//! CLI coverage for `mcautotune cache ls|rm` — the first slice of the
//! cache-lifecycle tooling (see ROADMAP "Batch tuning" follow-ups).

use mcautotune::coordinator::ResultCache;
use mcautotune::tuner::{cached_result, CachedTune, Method, TuneCache};
use std::path::PathBuf;
use std::process::Command;

const BIN: &str = env!("CARGO_BIN_EXE_mcautotune");

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mcat_clicache_{}_{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run(args: &[&str]) -> (bool, String) {
    let out = Command::new(BIN).args(args).output().expect("spawn mcautotune");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn cache_ls_and_rm_roundtrip() {
    let dir = temp_dir("roundtrip");
    let path = dir.join("cache.json");
    let path_s = path.to_str().unwrap();

    // seed two entries through the library
    let native_desc = "model=minimum size=64 gmt=3 method=exhaustive prop=over_time";
    {
        let mut c = ResultCache::open(&path).unwrap();
        c.store(
            native_desc,
            &cached_result(
                Method::Exhaustive,
                CachedTune { wg: 8, ts: 2, t_min: 36, steps: 9 },
                "seed",
            ),
        );
        c.store(
            "engine=promela pml=0123456789abcdef method=exhaustive prop=over_time",
            &cached_result(
                Method::Exhaustive,
                CachedTune { wg: 4, ts: 4, t_min: 528, steps: 21 },
                "seed",
            ),
        );
        c.save().unwrap();
    }

    let (ok, text) = run(&["cache", "ls", path_s]);
    assert!(ok, "cache ls failed: {}", text);
    assert!(text.contains("2 entries"), "{}", text);
    assert!(text.contains("model=minimum size=64"), "{}", text);
    assert!(text.contains("engine=promela pml="), "{}", text);
    assert!(text.contains("WG=8 TS=2 t_min=36"), "{}", text);

    let (ok, text) = run(&["cache", "rm", path_s, "engine=promela"]);
    assert!(ok, "cache rm failed: {}", text);
    assert!(text.contains("removed 1 entry"), "{}", text);

    let (ok, text) = run(&["cache", "ls", path_s]);
    assert!(ok);
    assert!(text.contains("1 entry"), "{}", text);
    assert!(!text.contains("engine=promela"), "{}", text);

    // the file on disk agrees with the library view
    let mut c = ResultCache::open(&path).unwrap();
    assert_eq!(c.len(), 1);
    assert!(c.lookup(native_desc).is_some());

    // removing nothing reports zero and keeps the file valid
    let (ok, text) = run(&["cache", "rm", path_s, "no-such-needle"]);
    assert!(ok);
    assert!(text.contains("removed 0 entries"), "{}", text);
    assert_eq!(ResultCache::open(&path).unwrap().len(), 1);

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cache_rm_on_missing_file_errors_and_bad_action_is_rejected() {
    let dir = temp_dir("errors");
    let missing = dir.join("nope.json");
    let (ok, text) = run(&["cache", "rm", missing.to_str().unwrap(), "x"]);
    assert!(!ok, "rm on a missing file must fail: {}", text);
    assert!(!missing.exists(), "rm must not create the file");

    let (ok, text) = run(&["cache", "frobnicate", "x.json"]);
    assert!(!ok);
    assert!(text.contains("unknown cache action"), "{}", text);

    // bare `cache` prints usage and succeeds
    let (ok, text) = run(&["cache"]);
    assert!(ok);
    assert!(text.contains("ls <file>"), "{}", text);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn worker_status_smoke_on_a_planned_dir() {
    use mcautotune::coordinator::{BatchOptions, TaskDir, TuningJob};
    let dir = temp_dir("status");
    let tasks = dir.join("tasks");
    let jobs = vec![TuningJob::new(mcautotune::coordinator::ModelKind::Minimum, 16)];
    let mut cache = ResultCache::in_memory();
    TaskDir::new(&tasks).plan(&jobs, &BatchOptions::default(), &mut cache).unwrap();

    let (ok, text) = run(&["worker", "--status", tasks.to_str().unwrap()]);
    assert!(ok, "worker --status failed: {}", text);
    assert!(text.contains("available"), "{}", text);
    assert!(text.contains("0 done"), "{}", text);
    std::fs::remove_dir_all(&dir).ok();
}
