//! Surrogate-guided search acceptance (ISSUE 10): the differential
//! guarantee (`--search surrogate` returns the *identical* optimum to
//! exhaustive tuning — cold, warm, or adversarially poisoned), the
//! oracle-call economy (strictly fewer checker invocations than both the
//! lattice size and the exhaustive bisection on warm runs), and the
//! determinism contract (`--frontier det` traces byte-identical across
//! re-runs and thread counts, search events included).

use mcautotune::checker::CheckOptions;
use mcautotune::coordinator::{ModelKind, ResultCache, TuningJob};
use mcautotune::model::TransitionSystem;
use mcautotune::obs::deterministic_lines;
use mcautotune::platform::{
    enumerate_tunings, AbstractModel, DataInit, Granularity, MinModel, PlatformConfig,
};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{
    surrogate_tune, tune, Method, Observation, SurrogateOptions, SurrogateReport,
};
use mcautotune::util::prop::{forall, Config};
use mcautotune::{prop_assert, prop_assert_eq};
use std::path::PathBuf;
use std::process::Command;
use std::sync::atomic::{AtomicU32, Ordering};

const BIN: &str = env!("CARGO_BIN_EXE_mcautotune");
const T_INI: Option<i64> = Some(1 << 17);

fn surrogate<M>(m: &M, size: u32, seeds: &[Observation]) -> SurrogateReport
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let lattice = enumerate_tunings(size).unwrap();
    surrogate_tune(
        m,
        &CheckOptions::default(),
        &SwarmConfig::default(),
        T_INI,
        &lattice,
        size,
        seeds,
        &SurrogateOptions::default(),
    )
    .unwrap()
}

/// Adversarial cache contents: absurd times, off-lattice coordinates,
/// contradicting near-duplicates — enough rows to clear `min_obs`, wrong
/// enough that a trusting proposer would rank the lattice upside down.
fn poison(size: u32) -> Vec<Observation> {
    vec![
        Observation { wg: 1, ts: 1, size, time: 1 },
        Observation { wg: 1, ts: 1, size, time: i64::MAX / 4 },
        Observation { wg: 4096, ts: 4096, size, time: -9 },
        Observation { wg: 2, ts: 2, size: size.max(2) / 2, time: 0 },
    ]
}

/// The core differential: exhaustive once, then surrogate with an empty
/// cache (must fall back, same optimum) and with a poisoned cache (must
/// take the surrogate path, certificate must force the same optimum,
/// oracle calls must stay strictly below the lattice size).
fn differential<M>(name: &str, m: &M, size: u32)
where
    M: TransitionSystem + Sync,
    M::State: Send,
{
    let ex = tune(m, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), T_INI)
        .unwrap();

    let cold = surrogate(m, size, &[]);
    assert!(cold.fell_back, "{}: empty cache must fall back", name);
    assert_eq!(cold.result.t_min, ex.t_min, "{}: fallback t_min", name);
    assert_eq!(
        (cold.result.optimal.wg, cold.result.optimal.ts),
        (ex.optimal.wg, ex.optimal.ts),
        "{}: fallback witness",
        name
    );

    let rep = surrogate(m, size, &poison(size));
    assert!(!rep.fell_back, "{}: poisoned cache clears min_obs", name);
    assert_eq!(rep.result.t_min, ex.t_min, "{}: poisoned t_min", name);
    assert_eq!(
        (rep.result.optimal.wg, rep.result.optimal.ts),
        (ex.optimal.wg, ex.optimal.ts),
        "{}: poisoned witness",
        name
    );
    let lattice = enumerate_tunings(size).unwrap().len() as u64;
    assert!(
        rep.oracle_calls < lattice,
        "{}: {} oracle calls not below the {}-config lattice",
        name,
        rep.oracle_calls,
        lattice
    );
    assert!(rep.proposals > 0, "{}: surrogate path must propose", name);
}

// ------------------------------------------------- differential corpus --

/// 17 tunable models spanning both native families, sizes 16..=128, three
/// GMT ratios, PE-count variety, and both granularities. Every one must
/// satisfy the cold and poisoned differential.
#[test]
fn surrogate_matches_exhaustive_on_the_17_model_corpus() {
    let mut n = 0;
    for &size in &[16u32, 32, 64] {
        for &gmt in &[2u32, 3, 4] {
            let m = MinModel::new(size, 4, gmt, DataInit::Descending, Granularity::Phase).unwrap();
            differential(&format!("min-{}-gmt{}", size, gmt), &m, size);
            n += 1;
        }
    }
    differential("min-128-paper", &MinModel::paper(128, 4).unwrap(), 128);
    n += 1;
    for &(size, np) in &[(16u32, 2u32), (32, 8)] {
        let m = MinModel::new(size, np, 3, DataInit::Descending, Granularity::Phase).unwrap();
        differential(&format!("min-{}-np{}", size, np), &m, size);
        n += 1;
    }
    let m = MinModel::new(16, 4, 3, DataInit::Descending, Granularity::Tick).unwrap();
    differential("min-16-tick", &m, 16);
    n += 1;
    for &size in &[16u32, 32, 64] {
        let m = AbstractModel::new(size, PlatformConfig::default(), Granularity::Phase).unwrap();
        differential(&format!("abs-{}", size), &m, size);
        n += 1;
    }
    let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Tick).unwrap();
    differential("abs-16-tick", &m, 16);
    n += 1;
    assert_eq!(n, 17, "the corpus contract is exactly 17 models");
}

// ------------------------------------------------- oracle-call economy --

/// Warm-start across input sizes of one family: observations harvested
/// from exhaustive tunes at 16/32/64 drive a surrogate run at 128 that
/// (a) takes the surrogate path, (b) returns the identical optimum, and
/// (c) spends strictly fewer checker invocations than both the lattice
/// and the exhaustive bisection it replaces.
#[test]
fn warm_observations_cut_oracle_calls_below_the_exhaustive_count() {
    use mcautotune::tuner::harvest_observations;
    let mut seeds = Vec::new();
    for &size in &[16u32, 32, 64] {
        let m = MinModel::paper(size, 4).unwrap();
        let r =
            tune(&m, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), T_INI)
                .unwrap();
        seeds.extend(harvest_observations(&r, size));
    }
    assert!(seeds.len() >= 3, "three sizes must harvest >= min_obs rows, got {}", seeds.len());

    let m = MinModel::paper(128, 4).unwrap();
    let ex = tune(&m, Method::Exhaustive, &CheckOptions::default(), &SwarmConfig::default(), T_INI)
        .unwrap();
    let exhaustive_calls = ex.log.len() as u64; // one log line per Cex(T) query
    let rep = surrogate(&m, 128, &seeds);
    assert!(!rep.fell_back);
    assert_eq!(rep.result.t_min, ex.t_min);
    let lattice = enumerate_tunings(128).unwrap().len() as u64;
    assert!(rep.oracle_calls < lattice, "{} vs lattice {}", rep.oracle_calls, lattice);
    assert!(
        rep.oracle_calls < exhaustive_calls,
        "warm surrogate must undercut the exhaustive bisection: {} vs {}",
        rep.oracle_calls,
        exhaustive_calls
    );
}

// ------------------------------------------------------- determinism --

/// Same inputs → the same report, field for field (the exploration RNG
/// is seeded, k-NN ties break canonically, the oracle is deterministic).
#[test]
fn surrogate_reports_are_reproducible_in_process() {
    let m = MinModel::paper(64, 4).unwrap();
    let seeds = poison(64);
    let a = surrogate(&m, 64, &seeds);
    let b = surrogate(&m, 64, &seeds);
    assert_eq!(a.result.t_min, b.result.t_min);
    assert_eq!((a.result.optimal.wg, a.result.optimal.ts), (b.result.optimal.wg, b.result.optimal.ts));
    assert_eq!(a.oracle_calls, b.oracle_calls);
    assert_eq!(a.proposals, b.proposals);
    assert_eq!(a.evals, b.evals);
    assert_eq!(a.result.log, b.result.log);
}

fn temp(tag: &str) -> PathBuf {
    static SEQ: AtomicU32 = AtomicU32::new(0);
    std::env::temp_dir().join(format!(
        "mcat_surr_{}_{}_{}",
        tag,
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn run_bin(args: &[&str]) -> String {
    let out = Command::new(BIN).args(args).output().expect("spawn mcautotune");
    assert!(
        out.status.success(),
        "mcautotune {:?} failed:\nstdout: {}\nstderr: {}",
        args,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn search_lines(text: &str) -> Vec<String> {
    text.lines().filter(|l| l.contains("\"k\":\"search\"")).map(String::from).collect()
}

/// `tune --search surrogate --frontier det`: the run event and every
/// content-only `search` event must be byte-identical across re-runs and
/// across thread counts. Each run gets its own copy of an identically
/// seeded cache (a shared cache would turn the later runs into lookup
/// hits and erase the search events being compared).
#[test]
fn cli_surrogate_det_traces_byte_identical_across_runs_and_threads() {
    let seed_cache = temp("seedcache");
    {
        let job = TuningJob::new(ModelKind::Minimum, 32);
        let family = job.obs_family();
        let mut c = ResultCache::open(&seed_cache).unwrap();
        c.record_observation(&family, Observation { wg: 4, ts: 2, size: 16, time: 300 });
        c.record_observation(&family, Observation { wg: 8, ts: 2, size: 16, time: 200 });
        c.record_observation(&family, Observation { wg: 8, ts: 4, size: 64, time: 900 });
        c.save().unwrap();
    }

    let mut traces = Vec::new();
    for (i, threads) in ["1", "1", "4"].iter().enumerate() {
        let cache = temp(&format!("cache{}", i));
        std::fs::copy(&seed_cache, &cache).unwrap();
        let trace = temp(&format!("trace{}", i));
        run_bin(&[
            "tune",
            "--model",
            "minimum",
            "--size",
            "32",
            "--search",
            "surrogate",
            "--cache",
            cache.to_str().unwrap(),
            "--frontier",
            "det",
            "--threads",
            threads,
            "--trace",
            trace.to_str().unwrap(),
        ]);
        let text = std::fs::read_to_string(&trace).unwrap();
        mcautotune::obs::validate(&text).unwrap();
        // 3 seeded observations clear min_obs: the surrogate path ran
        let s = search_lines(&text);
        assert!(
            s.iter().any(|l| l.contains("\"kind\":\"certificate\"")),
            "run {} must reach the certificate (no fallback):\n{}",
            i,
            text
        );
        assert!(!s.iter().any(|l| l.contains("\"kind\":\"fallback\"")), "run {} fell back", i);
        // the surrogate run records its exact evals for future warm-starts
        let c = ResultCache::open(&cache).unwrap();
        assert!(c.observation_count() > 3, "run {} must add observations", i);
        traces.push(text);
        std::fs::remove_file(&cache).ok();
        std::fs::remove_file(&trace).ok();
    }
    let (a, b, c) = (&traces[0], &traces[1], &traces[2]);
    assert_eq!(deterministic_lines(a), deterministic_lines(b), "re-run changed the run event");
    assert_eq!(deterministic_lines(a), deterministic_lines(c), "threads changed the run event");
    let sa = search_lines(a);
    assert!(!sa.is_empty(), "surrogate runs must emit search events");
    assert_eq!(sa, search_lines(b), "re-run changed the search events");
    assert_eq!(sa, search_lines(c), "thread count changed the search events");
    assert!(
        deterministic_lines(a)[0].contains("\"search\":\"surrogate\""),
        "run event must carry the search mode: {}",
        deterministic_lines(a)[0]
    );
    std::fs::remove_file(&seed_cache).ok();
}

// --------------------------------------------------------- property --

/// Randomized minimum models and randomized (possibly garbage) seed
/// observations: the surrogate answer always equals the closed-form
/// optimum, and the oracle-call bound holds whenever the surrogate path
/// is taken.
#[test]
fn prop_surrogate_matches_the_closed_form_optimum() {
    forall(
        "surrogate == closed-form optimum",
        Config { cases: 10, ..Default::default() },
        |r| {
            let size = 16u32 << r.below(3); // 16 | 32 | 64
            let np = 2u32 << r.below(3); // 2 | 4 | 8
            let gmt = 2 + r.below(4) as u32;
            let seeds: Vec<Observation> = (0..3 + r.below(4))
                .map(|_| Observation {
                    wg: 1u32 << r.below(8),
                    ts: 1u32 << r.below(8),
                    size: 16u32 << r.below(3),
                    time: r.below(1 << 20) as i64 - 1000,
                })
                .collect();
            (size, np, gmt, seeds)
        },
        |(size, np, gmt, seeds)| {
            let m = MinModel::new(*size, *np, *gmt, DataInit::Descending, Granularity::Phase)
                .map_err(|e| e.to_string())?;
            let (opt_time, _) = m.optimum();
            let rep = surrogate(&m, *size, seeds);
            prop_assert_eq!(rep.result.t_min, opt_time as i64);
            if !rep.fell_back {
                let lattice = enumerate_tunings(*size).unwrap().len() as u64;
                prop_assert!(
                    rep.oracle_calls < lattice,
                    "{} oracle calls on a {}-config lattice",
                    rep.oracle_calls,
                    lattice
                );
            }
            Ok(())
        },
    );
}
