//! Differential conformance: cross-process batch draining (worker mode)
//! must be indistinguishable from the single-process engine.
//!
//! The acceptance property: an N-worker multi-process drain of a batch —
//! spawned as real `mcautotune` processes on the test binary's own
//! executable — yields best-configs, verdicts and cache entries identical
//! to a single-process `run_batch` on the same specs, including after a
//! simulated worker crash mid-lease (the stale lease is re-leased and the
//! final report is unchanged).

use mcautotune::coordinator::{
    run_batch, BatchOptions, BatchReport, ResultCache, TaskDir, TuningJob,
};
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

const BIN: &str = env!("CARGO_BIN_EXE_mcautotune");

fn temp(tag: &str) -> PathBuf {
    static SEQ: AtomicU32 = AtomicU32::new(0);
    std::env::temp_dir().join(format!(
        "mcat_dist_{}_{}_{}",
        tag,
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// The differential workload: multiple engines, an adaptive-shard job, a
/// within-batch duplicate, and a Promela job whose source must survive
/// the trip through the task manifests.
const SPEC: &str = "\
job minimum size=64 np=4 gmt=3 shards=4
job minimum size=32 np=4 gmt=3
job minimum size=32 np=4 gmt=3 name=dup-of-32
job abstract size=16 gmt=10 shards=2
job minimum size=16 engine=promela shards=2 name=pml16
";

/// A smaller workload for the crash-recovery schedule.
const CRASH_SPEC: &str = "\
job minimum size=32 np=4 gmt=3 shards=3
job abstract size=16 gmt=10
";

fn reference_report(spec: &str, cache_path: &Path) -> BatchReport {
    let jobs = TuningJob::parse_spec(spec).unwrap();
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    let mut cache = ResultCache::open(cache_path).unwrap();
    run_batch(&jobs, &opts, &mut cache).unwrap()
}

/// Everything the differential suite pins. Wall-clock-dependent fields
/// (elapsed, first-trail discovery latency, queue steal counts) are
/// legitimately nondeterministic and excluded.
fn assert_reports_identical(single: &BatchReport, multi: &BatchReport) {
    assert_eq!(single.outcomes.len(), multi.outcomes.len());
    for (s, m) in single.outcomes.iter().zip(&multi.outcomes) {
        assert_eq!(s.job, m.job, "job specs must round-trip");
        assert_eq!(s.cached, m.cached, "job `{}`: cached flag", s.job.name);
        assert_eq!(s.shards, m.shards, "job `{}`: shard count", s.job.name);
        assert_eq!(s.result.method, m.result.method, "job `{}`", s.job.name);
        assert_eq!(s.result.t_min, m.result.t_min, "job `{}`: verdict (t_min)", s.job.name);
        let (so, mo) = (&s.result.optimal, &m.result.optimal);
        assert_eq!(
            (so.wg, so.ts, so.time, so.steps),
            (mo.wg, mo.ts, mo.time, mo.steps),
            "job `{}`: best config",
            s.job.name
        );
        assert_eq!(
            s.result.states_explored, m.result.states_explored,
            "job `{}`: exploration is deterministic, so states must agree",
            s.job.name
        );
        assert_eq!(s.plan, m.plan, "job `{}`: shard budget plans", s.job.name);
        assert_eq!(
            s.result.log.len(),
            m.result.log.len(),
            "job `{}`: merged shard logs",
            s.job.name
        );
    }
    assert_eq!(single.cache_hits, multi.cache_hits);
    assert_eq!(single.cache_misses, multi.cache_misses);
}

fn assert_cache_files_identical(a: &Path, b: &Path) {
    let a_text = std::fs::read_to_string(a).unwrap();
    let b_text = std::fs::read_to_string(b).unwrap();
    assert_eq!(a_text, b_text, "cache files must be byte-identical");
}

fn run_bin(args: &[&str]) -> String {
    let out = Command::new(BIN).args(args).output().expect("spawn mcautotune");
    assert!(
        out.status.success(),
        "mcautotune {:?} failed:\nstdout: {}\nstderr: {}",
        args,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn two_worker_processes_match_single_process_run_batch() {
    let spec_path = temp("spec");
    std::fs::write(&spec_path, SPEC).unwrap();
    let cache_single = temp("cache_single");
    let cache_multi = temp("cache_multi");
    let dir = temp("tasks");
    let dir_s = dir.to_str().unwrap();

    let single = reference_report(SPEC, &cache_single);

    // plan → two concurrent worker processes → merge
    let plan_out = run_bin(&[
        "batch",
        spec_path.to_str().unwrap(),
        "--task-dir",
        dir_s,
        "--plan-only",
        "--cache",
        cache_multi.to_str().unwrap(),
        "--workers",
        "2",
    ]);
    assert!(plan_out.contains("planned"), "unexpected plan output: {}", plan_out);
    let workers: Vec<_> = (0..2)
        .map(|_| Command::new(BIN).args(["worker", dir_s]).spawn().expect("spawn worker"))
        .collect();
    for mut w in workers {
        let status = w.wait().expect("worker wait");
        assert!(status.success(), "worker process failed");
    }
    let merge_out = run_bin(&["merge", dir_s]);
    assert!(merge_out.contains("pml16"), "merged report missing jobs: {}", merge_out);

    // re-merge through the library for a structural comparison (the merge
    // is idempotent: same results, same cache entries)
    let mut cache = ResultCache::open(&cache_multi).unwrap();
    let multi = TaskDir::new(&dir).merge(&mut cache).unwrap();
    assert_reports_identical(&single, &multi);
    assert_cache_files_identical(&cache_single, &cache_multi);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&cache_single).ok();
    std::fs::remove_file(&cache_multi).ok();
}

#[test]
fn in_process_drain_matches_run_batch() {
    // the protocol itself (no subprocesses): plan → 2-thread drain → merge
    let cache_single = temp("cache_single");
    let cache_multi = temp("cache_multi");
    let dir = temp("tasks");

    let single = reference_report(CRASH_SPEC, &cache_single);

    let jobs = TuningJob::parse_spec(CRASH_SPEC).unwrap();
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    let td = TaskDir::new(&dir);
    let mut cache = ResultCache::open(&cache_multi).unwrap();
    let summary = td.plan(&jobs, &opts, &mut cache).unwrap();
    assert!(summary.tasks >= 4, "3 pinned shards + at least one more: {:?}", summary);
    let stats = td.drain(2, false).unwrap();
    assert!(stats.complete);
    assert_eq!(stats.executed, summary.tasks as u64, "this drain ran every task");
    let multi = td.merge(&mut cache).unwrap();

    assert_reports_identical(&single, &multi);
    assert_cache_files_identical(&cache_single, &cache_multi);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&cache_single).ok();
    std::fs::remove_file(&cache_multi).ok();
}

#[test]
fn crash_mid_lease_is_re_leased_and_report_stays_identical() {
    let spec_path = temp("spec");
    std::fs::write(&spec_path, CRASH_SPEC).unwrap();
    let cache_single = temp("cache_single");
    let cache_multi = temp("cache_multi");
    let dir = temp("tasks");
    let dir_s = dir.to_str().unwrap();

    let single = reference_report(CRASH_SPEC, &cache_single);

    run_bin(&[
        "batch",
        spec_path.to_str().unwrap(),
        "--task-dir",
        dir_s,
        "--plan-only",
        "--cache",
        cache_multi.to_str().unwrap(),
        "--workers",
        "2",
    ]);

    // a worker leases a task and "crashes": no heartbeat, no result. The
    // lease file stays behind with a fresh mtime.
    let crashed = TaskDir::new(&dir);
    let abandoned = crashed.lease().unwrap().expect("a task to abandon");
    let abandoned_id = abandoned.spec.id.clone();
    drop(abandoned);

    // a real process is killed mid-drain too (whatever it was doing)
    let mut victim = Command::new(BIN)
        .args(["worker", dir_s, "--ttl-ms", "400", "--poll-ms", "50"])
        .spawn()
        .expect("spawn victim worker");
    std::thread::sleep(Duration::from_millis(200));
    let _ = victim.kill();
    let _ = victim.wait();

    // recovery: a fresh worker with a short TTL must re-lease the stale
    // leases (the abandoned one is not stale until 400ms after its claim,
    // and no other process is alive to finish it) and drain to completion
    let out = run_bin(&["worker", dir_s, "--ttl-ms", "400", "--poll-ms", "50"]);
    assert!(out.contains("batch complete"), "recovery worker did not finish: {}", out);
    assert!(
        !out.contains(" 0 reclaimed"),
        "recovery must have re-leased at least the abandoned task: {}",
        out
    );
    assert!(
        dir.join(format!("{}.result.json", abandoned_id)).exists(),
        "the abandoned task must have been re-leased and completed"
    );

    let mut cache = ResultCache::open(&cache_multi).unwrap();
    let multi = TaskDir::new(&dir).merge(&mut cache).unwrap();
    assert_reports_identical(&single, &multi);
    assert_cache_files_identical(&cache_single, &cache_multi);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&cache_single).ok();
    std::fs::remove_file(&cache_multi).ok();
}
