//! Property-based tests over the coordinator invariants (util::prop is the
//! in-tree proptest replacement — see Cargo.toml note).

use mcautotune::checker::{check, CheckOptions, StoreKind};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::platform::{
    enumerate_tunings, geometry, AbstractModel, DataInit, Granularity, MinModel, PlatformConfig,
};
use mcautotune::prop_assert;
use mcautotune::prop_assert_eq;
use mcautotune::util::prop::{forall, Config};
use mcautotune::util::rng::Xoshiro256;

fn pow2(r: &mut Xoshiro256, lo_pow: u32, hi_pow: u32) -> u32 {
    1 << r.range_i64(lo_pow as i64, hi_pow as i64) as u32
}

#[test]
fn prop_geometry_invariants() {
    forall(
        "geometry-invariants",
        Config::default(),
        |r| {
            let size = pow2(r, 3, 10);
            let plat = PlatformConfig {
                nd: r.range_i64(1, 4) as u32,
                nu: r.range_i64(1, 4) as u32,
                np: pow2(r, 0, 6),
                gmt: r.range_i64(1, 20) as u32,
            };
            (size, plat)
        },
        |&(size, plat)| {
            for t in enumerate_tunings(size).unwrap() {
                let g = geometry(size, t, &plat);
                prop_assert!(g.wgs >= 1, "wgs {} < 1 for {:?}", g.wgs, t);
                prop_assert!(g.nwd >= 1 && g.nwd <= plat.nd);
                prop_assert!(g.nwu >= 1 && g.nwu <= plat.nu);
                prop_assert!(g.nwe >= 1 && g.nwe <= plat.np && g.nwe <= t.wg);
                // enough rounds to serve every work item
                let served = g.rounds as u64 * g.all_nwe() as u64;
                let items = g.wgs as u64 * t.wg as u64;
                prop_assert!(served >= items, "{} rounds serve {} < {} items", g.rounds, served, items);
                // no more rounds than necessary (one extra at most from ceil)
                prop_assert!((g.rounds as u64 - 1) * g.all_nwe() as u64 <= items);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_min_model_always_computes_true_min() {
    forall(
        "min-model-correctness",
        Config { cases: 24, ..Default::default() },
        |r| {
            let size = pow2(r, 2, 7);
            let np = pow2(r, 0, 5);
            let gmt = r.range_i64(1, 6) as u32;
            let seed = r.next_u64();
            (size, np, gmt, seed)
        },
        |&(size, np, gmt, seed)| {
            let m = MinModel::new(size, np, gmt, DataInit::Seeded(seed), Granularity::Phase)
                .map_err(|e| e.to_string())?;
            let prop =
                SafetyLtl::parse(&format!("G(FIN -> result == {})", m.true_min())).unwrap();
            let rep = check(&m, &prop, &CheckOptions::default()).map_err(|e| e.to_string())?;
            prop_assert!(rep.exhausted, "not exhausted");
            prop_assert!(!rep.found(), "some schedule computed a wrong minimum");
            Ok(())
        },
    );
}

#[test]
fn prop_abstract_terminal_times_match_formula() {
    forall(
        "abstract-terminal-times",
        Config { cases: 24, ..Default::default() },
        |r| {
            let size = pow2(r, 2, 7);
            let plat = PlatformConfig {
                nd: r.range_i64(1, 3) as u32,
                nu: r.range_i64(1, 3) as u32,
                np: pow2(r, 0, 4),
                gmt: r.range_i64(1, 12) as u32,
            };
            (size, plat)
        },
        |&(size, plat)| {
            let m = AbstractModel::new(size, plat, Granularity::Phase)
                .map_err(|e| e.to_string())?;
            // exhaustively reach all FIN states; compare against formula
            let mut o = CheckOptions::default();
            o.collect_all = true;
            let rep = check(&m, &SafetyLtl::non_termination(), &o).map_err(|e| e.to_string())?;
            prop_assert!(rep.exhausted);
            prop_assert_eq!(rep.violations.len(), m.tunings().len());
            for v in &rep.violations {
                let s = v.trail.last();
                let wg = m.eval_var(s, "WG").unwrap() as u32;
                let ts = m.eval_var(s, "TS").unwrap() as u32;
                let t = m
                    .tunings()
                    .iter()
                    .find(|t| t.wg == wg && t.ts == ts)
                    .copied()
                    .ok_or("unknown tuning in trail")?;
                prop_assert_eq!(m.eval_var(s, "time").unwrap(), m.predicted_time(t) as i64);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_store_kinds_agree_on_random_streams() {
    use mcautotune::checker::VisitedStore;
    forall(
        "store-agreement",
        Config { cases: 32, ..Default::default() },
        |r| {
            let n = r.range_i64(1, 400) as usize;
            let dup_every = r.range_i64(2, 10) as usize;
            let seed = r.next_u64();
            (n, dup_every, seed)
        },
        |&(n, dup_every, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let mut full = VisitedStore::new(StoreKind::Full);
            let mut compact = VisitedStore::new(StoreKind::HashCompact);
            let mut history: Vec<Vec<u8>> = Vec::new();
            for i in 0..n {
                let item: Vec<u8> = if i % dup_every == 0 && !history.is_empty() {
                    history[rng.below(history.len() as u64) as usize].clone()
                } else {
                    (0..rng.range_i64(1, 24)).map(|_| rng.next_u64() as u8).collect()
                };
                let a = full.insert(&item);
                let b = compact.insert(&item);
                prop_assert_eq!(a, b);
                history.push(item);
            }
            prop_assert_eq!(full.len(), compact.len());
            Ok(())
        },
    );
}

#[test]
fn prop_ltl_parser_roundtrips_random_formulas() {
    // generate random comparison trees, evaluate against random envs, and
    // check the parser+evaluator agrees with a direct recursive oracle
    #[derive(Debug)]
    enum F {
        Cmp(&'static str, i64),
        And(Box<F>, Box<F>),
        Or(Box<F>, Box<F>),
        Not(Box<F>),
    }

    fn gen_f(r: &mut Xoshiro256, depth: u32) -> F {
        if depth == 0 || r.chance(2, 5) {
            let var = *r.pick(&["a", "b", "c"]);
            F::Cmp(var, r.range_i64(-3, 3))
        } else {
            match r.below(3) {
                0 => F::And(Box::new(gen_f(r, depth - 1)), Box::new(gen_f(r, depth - 1))),
                1 => F::Or(Box::new(gen_f(r, depth - 1)), Box::new(gen_f(r, depth - 1))),
                _ => F::Not(Box::new(gen_f(r, depth - 1))),
            }
        }
    }

    fn render(f: &F) -> String {
        match f {
            F::Cmp(v, k) => format!("({} > {})", v, k),
            F::And(a, b) => format!("({} && {})", render(a), render(b)),
            F::Or(a, b) => format!("({} || {})", render(a), render(b)),
            F::Not(a) => format!("(!{})", render(a)),
        }
    }

    fn eval_f(f: &F, env: &[(&str, i64)]) -> bool {
        match f {
            F::Cmp(v, k) => env.iter().find(|(n, _)| n == v).unwrap().1 > *k,
            F::And(a, b) => eval_f(a, env) && eval_f(b, env),
            F::Or(a, b) => eval_f(a, env) || eval_f(b, env),
            F::Not(a) => !eval_f(a, env),
        }
    }

    forall(
        "ltl-parser-oracle",
        Config { cases: 128, ..Default::default() },
        |r| {
            let f = gen_f(r, 4);
            let env = [
                ("a", r.range_i64(-5, 5)),
                ("b", r.range_i64(-5, 5)),
                ("c", r.range_i64(-5, 5)),
            ];
            (render(&f), eval_f(&f, &env), env)
        },
        |(src, want, env)| {
            let p = SafetyLtl::parse(&format!("G({})", src)).map_err(|e| e.to_string())?;
            let lookup =
                |n: &str| env.iter().find(|(k, _)| *k == n).map(|(_, v)| *v);
            let got = p.holds(&lookup).map_err(|e| e.to_string())?;
            prop_assert_eq!(got, *want);
            Ok(())
        },
    );
}

#[test]
fn prop_task_manifests_roundtrip() {
    // worker mode: every TaskSpec serialized to a JSON manifest and
    // re-parsed must be equal — across engines, inlined sources with
    // JSON-hostile bytes, store kinds, beyond-i64 budgets and unset
    // time budgets
    use mcautotune::checker::Frontier;
    use mcautotune::coordinator::{
        JobEngine, ModelKind, ShardPlan, TaskSpec, TuningJob, TuningShard,
    };
    use mcautotune::swarm::SwarmConfig;
    use mcautotune::tuner::Method;
    use std::time::Duration;

    fn gen_spec(r: &mut Xoshiro256) -> TaskSpec {
        let mut job = TuningJob::new(
            if r.chance(1, 2) { ModelKind::Minimum } else { ModelKind::Abstract },
            pow2(r, 2, 8),
        );
        job.name = match r.below(3) {
            0 => format!("job-{}", r.below(100)),
            1 => format!("π \"{}\"\n\ttricky\\name", r.below(100)),
            _ => String::new(),
        };
        job.engine = if r.chance(1, 2) { JobEngine::Promela } else { JobEngine::Native };
        job.source = match r.below(3) {
            0 => None,
            1 => Some("int x;\nactive proctype main() { x = 1 }".into()),
            _ => Some(format!("/* π \"escaped\" */\nint y = {};", r.below(1000))),
        };
        job.plat.nd = r.range_i64(1, 4) as u32;
        job.plat.gmt = r.range_i64(1, 20) as u32;
        job.method = if r.chance(1, 2) { Method::Exhaustive } else { Method::Swarm };
        job.granularity =
            if r.chance(1, 2) { Granularity::Tick } else { Granularity::Phase };
        job.shards = r.below(9) as u32;
        job.search = if r.chance(1, 2) {
            mcautotune::tuner::SearchMode::Surrogate
        } else {
            mcautotune::tuner::SearchMode::Exhaustive
        };
        let store = match r.below(4) {
            0 => StoreKind::Full,
            1 => StoreKind::HashCompact,
            2 => StoreKind::Spill,
            _ => StoreKind::Bitstate {
                log2_bits: r.range_i64(10, 30) as u8,
                hashes: r.range_i64(1, 7) as u8,
            },
        };
        let check = CheckOptions {
            store,
            max_depth: r.below(1 << 30) as usize,
            max_states: if r.chance(1, 3) { u64::MAX } else { r.next_u64() },
            memory_budget: r.next_u64() >> (r.below(32) as u32),
            time_budget: if r.chance(1, 2) {
                None
            } else {
                Some(Duration::from_nanos(r.next_u64() >> 16))
            },
            collect_all: r.chance(1, 2),
            max_errors: r.below(1 << 20) as usize,
            order: if r.chance(1, 2) {
                mcautotune::checker::Order::InOrder
            } else {
                mcautotune::checker::Order::Random(r.next_u64())
            },
            threads: r.below(64) as u32,
            expected_states: r.next_u64(),
            frontier: if r.chance(1, 2) { Frontier::Async } else { Frontier::Deterministic },
            por: r.chance(1, 2),
            compress: if r.chance(1, 3) {
                mcautotune::checker::Compression::Collapse
            } else {
                mcautotune::checker::Compression::None
            },
            spill_dir: if r.chance(1, 2) {
                None
            } else {
                Some(std::path::PathBuf::from(format!("/tmp/spill π {}", r.below(100))))
            },
        };
        TaskSpec {
            id: format!("j{:03}-s{:03}", r.below(40), r.below(16)),
            job_index: r.below(40) as usize,
            shard_index: r.below(16) as usize,
            desc: format!("model=minimum size={} \"quoted\" π", r.below(1 << 20)),
            job,
            plan: ShardPlan {
                shard: TuningShard {
                    wg_min: r.below(1 << 10) as u32,
                    wg_max: if r.chance(1, 2) { u32::MAX } else { r.below(1 << 10) as u32 },
                    ts_min: r.below(1 << 10) as u32,
                    ts_max: if r.chance(1, 2) { u32::MAX } else { r.below(1 << 10) as u32 },
                },
                weight: r.next_u64(),
                t_ini: r.range_i64(1, i64::MAX / 2),
                check,
                seeds: (0..r.below(4))
                    .map(|_| mcautotune::tuner::Observation {
                        wg: r.below(1 << 10) as u32,
                        ts: r.below(1 << 10) as u32,
                        size: r.below(1 << 20) as u32,
                        time: r.range_i64(1, i64::MAX / 4),
                    })
                    .collect(),
            },
            swarm: SwarmConfig {
                workers: r.range_i64(1, 32) as u32,
                seed: r.next_u64(),
                log2_bits: r.range_i64(10, 30) as u8,
                hashes: r.range_i64(1, 7) as u8,
                max_depth: r.below(1 << 30) as usize,
                time_budget: Duration::from_millis(r.below(1 << 20)),
                max_errors_per_worker: r.below(1 << 10) as usize,
            },
        }
    }

    forall(
        "task-manifest-roundtrip",
        Config { cases: 64, ..Default::default() },
        gen_spec,
        |spec| {
            let text = spec.to_json().render();
            let back = TaskSpec::parse(&text).map_err(|e| format!("{:#}", e))?;
            prop_assert_eq!(*spec, back);
            Ok(())
        },
    );
}

#[test]
fn lease_atomicity_exactly_one_winner_per_task_under_racing_threads() {
    // 8 threads race lease() on one directory: every task must be won by
    // exactly one thread (the atomic task->lease rename is the lock)
    use mcautotune::coordinator::{
        ModelKind, ShardPlan, TaskDir, TaskSpec, TuningJob, TuningShard,
    };
    use mcautotune::swarm::SwarmConfig;
    use std::sync::Mutex;

    let dir = std::env::temp_dir()
        .join(format!("mcat_lease_race_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let td = TaskDir::new(&dir); // default TTL: nothing goes stale mid-test
    let n_tasks = 24usize;
    for i in 0..n_tasks {
        td.write_task(&TaskSpec {
            id: format!("t{:03}", i),
            job_index: i,
            shard_index: 0,
            desc: format!("race task {}", i),
            job: TuningJob::new(ModelKind::Minimum, 16),
            plan: ShardPlan {
                shard: TuningShard::full(),
                weight: 1,
                t_ini: 1,
                check: CheckOptions::default(),
                seeds: Vec::new(),
            },
            swarm: SwarmConfig::default(),
        })
        .unwrap();
    }

    let winners: Mutex<Vec<String>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                let mine = TaskDir::new(&dir);
                let mut won = Vec::new();
                while let Some(leased) = mine.lease().unwrap() {
                    won.push(leased.spec.id.clone());
                }
                winners.lock().unwrap().extend(won);
            });
        }
    });

    let mut won = winners.into_inner().unwrap();
    won.sort();
    let expected: Vec<String> = (0..n_tasks).map(|i| format!("t{:03}", i)).collect();
    assert_eq!(won, expected, "every task leased exactly once across 8 racers");
    std::fs::remove_dir_all(&dir).ok();
}
