//! Property-based tests over the coordinator invariants (util::prop is the
//! in-tree proptest replacement — see Cargo.toml note).

use mcautotune::checker::{check, CheckOptions, StoreKind};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::platform::{
    enumerate_tunings, geometry, AbstractModel, DataInit, Granularity, MinModel, PlatformConfig,
};
use mcautotune::prop_assert;
use mcautotune::prop_assert_eq;
use mcautotune::util::prop::{forall, Config};
use mcautotune::util::rng::Xoshiro256;

fn pow2(r: &mut Xoshiro256, lo_pow: u32, hi_pow: u32) -> u32 {
    1 << r.range_i64(lo_pow as i64, hi_pow as i64) as u32
}

#[test]
fn prop_geometry_invariants() {
    forall(
        "geometry-invariants",
        Config::default(),
        |r| {
            let size = pow2(r, 3, 10);
            let plat = PlatformConfig {
                nd: r.range_i64(1, 4) as u32,
                nu: r.range_i64(1, 4) as u32,
                np: pow2(r, 0, 6),
                gmt: r.range_i64(1, 20) as u32,
            };
            (size, plat)
        },
        |&(size, plat)| {
            for t in enumerate_tunings(size).unwrap() {
                let g = geometry(size, t, &plat);
                prop_assert!(g.wgs >= 1, "wgs {} < 1 for {:?}", g.wgs, t);
                prop_assert!(g.nwd >= 1 && g.nwd <= plat.nd);
                prop_assert!(g.nwu >= 1 && g.nwu <= plat.nu);
                prop_assert!(g.nwe >= 1 && g.nwe <= plat.np && g.nwe <= t.wg);
                // enough rounds to serve every work item
                let served = g.rounds as u64 * g.all_nwe() as u64;
                let items = g.wgs as u64 * t.wg as u64;
                prop_assert!(served >= items, "{} rounds serve {} < {} items", g.rounds, served, items);
                // no more rounds than necessary (one extra at most from ceil)
                prop_assert!((g.rounds as u64 - 1) * g.all_nwe() as u64 <= items);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_min_model_always_computes_true_min() {
    forall(
        "min-model-correctness",
        Config { cases: 24, ..Default::default() },
        |r| {
            let size = pow2(r, 2, 7);
            let np = pow2(r, 0, 5);
            let gmt = r.range_i64(1, 6) as u32;
            let seed = r.next_u64();
            (size, np, gmt, seed)
        },
        |&(size, np, gmt, seed)| {
            let m = MinModel::new(size, np, gmt, DataInit::Seeded(seed), Granularity::Phase)
                .map_err(|e| e.to_string())?;
            let prop =
                SafetyLtl::parse(&format!("G(FIN -> result == {})", m.true_min())).unwrap();
            let rep = check(&m, &prop, &CheckOptions::default()).map_err(|e| e.to_string())?;
            prop_assert!(rep.exhausted, "not exhausted");
            prop_assert!(!rep.found(), "some schedule computed a wrong minimum");
            Ok(())
        },
    );
}

#[test]
fn prop_abstract_terminal_times_match_formula() {
    forall(
        "abstract-terminal-times",
        Config { cases: 24, ..Default::default() },
        |r| {
            let size = pow2(r, 2, 7);
            let plat = PlatformConfig {
                nd: r.range_i64(1, 3) as u32,
                nu: r.range_i64(1, 3) as u32,
                np: pow2(r, 0, 4),
                gmt: r.range_i64(1, 12) as u32,
            };
            (size, plat)
        },
        |&(size, plat)| {
            let m = AbstractModel::new(size, plat, Granularity::Phase)
                .map_err(|e| e.to_string())?;
            // exhaustively reach all FIN states; compare against formula
            let mut o = CheckOptions::default();
            o.collect_all = true;
            let rep = check(&m, &SafetyLtl::non_termination(), &o).map_err(|e| e.to_string())?;
            prop_assert!(rep.exhausted);
            prop_assert_eq!(rep.violations.len(), m.tunings().len());
            for v in &rep.violations {
                let s = v.trail.last();
                let wg = m.eval_var(s, "WG").unwrap() as u32;
                let ts = m.eval_var(s, "TS").unwrap() as u32;
                let t = m
                    .tunings()
                    .iter()
                    .find(|t| t.wg == wg && t.ts == ts)
                    .copied()
                    .ok_or("unknown tuning in trail")?;
                prop_assert_eq!(m.eval_var(s, "time").unwrap(), m.predicted_time(t) as i64);
            }
            Ok(())
        },
    );
}

#[test]
fn prop_store_kinds_agree_on_random_streams() {
    use mcautotune::checker::VisitedStore;
    forall(
        "store-agreement",
        Config { cases: 32, ..Default::default() },
        |r| {
            let n = r.range_i64(1, 400) as usize;
            let dup_every = r.range_i64(2, 10) as usize;
            let seed = r.next_u64();
            (n, dup_every, seed)
        },
        |&(n, dup_every, seed)| {
            let mut rng = Xoshiro256::new(seed);
            let mut full = VisitedStore::new(StoreKind::Full);
            let mut compact = VisitedStore::new(StoreKind::HashCompact);
            let mut history: Vec<Vec<u8>> = Vec::new();
            for i in 0..n {
                let item: Vec<u8> = if i % dup_every == 0 && !history.is_empty() {
                    history[rng.below(history.len() as u64) as usize].clone()
                } else {
                    (0..rng.range_i64(1, 24)).map(|_| rng.next_u64() as u8).collect()
                };
                let a = full.insert(&item);
                let b = compact.insert(&item);
                prop_assert_eq!(a, b);
                history.push(item);
            }
            prop_assert_eq!(full.len(), compact.len());
            Ok(())
        },
    );
}

#[test]
fn prop_ltl_parser_roundtrips_random_formulas() {
    // generate random comparison trees, evaluate against random envs, and
    // check the parser+evaluator agrees with a direct recursive oracle
    #[derive(Debug)]
    enum F {
        Cmp(&'static str, i64),
        And(Box<F>, Box<F>),
        Or(Box<F>, Box<F>),
        Not(Box<F>),
    }

    fn gen_f(r: &mut Xoshiro256, depth: u32) -> F {
        if depth == 0 || r.chance(2, 5) {
            let var = *r.pick(&["a", "b", "c"]);
            F::Cmp(var, r.range_i64(-3, 3))
        } else {
            match r.below(3) {
                0 => F::And(Box::new(gen_f(r, depth - 1)), Box::new(gen_f(r, depth - 1))),
                1 => F::Or(Box::new(gen_f(r, depth - 1)), Box::new(gen_f(r, depth - 1))),
                _ => F::Not(Box::new(gen_f(r, depth - 1))),
            }
        }
    }

    fn render(f: &F) -> String {
        match f {
            F::Cmp(v, k) => format!("({} > {})", v, k),
            F::And(a, b) => format!("({} && {})", render(a), render(b)),
            F::Or(a, b) => format!("({} || {})", render(a), render(b)),
            F::Not(a) => format!("(!{})", render(a)),
        }
    }

    fn eval_f(f: &F, env: &[(&str, i64)]) -> bool {
        match f {
            F::Cmp(v, k) => env.iter().find(|(n, _)| n == v).unwrap().1 > *k,
            F::And(a, b) => eval_f(a, env) && eval_f(b, env),
            F::Or(a, b) => eval_f(a, env) || eval_f(b, env),
            F::Not(a) => !eval_f(a, env),
        }
    }

    forall(
        "ltl-parser-oracle",
        Config { cases: 128, ..Default::default() },
        |r| {
            let f = gen_f(r, 4);
            let env = [
                ("a", r.range_i64(-5, 5)),
                ("b", r.range_i64(-5, 5)),
                ("c", r.range_i64(-5, 5)),
            ];
            (render(&f), eval_f(&f, &env), env)
        },
        |(src, want, env)| {
            let p = SafetyLtl::parse(&format!("G({})", src)).map_err(|e| e.to_string())?;
            let lookup =
                |n: &str| env.iter().find(|(k, _)| *k == n).map(|(_, v)| *v);
            let got = p.holds(&lookup).map_err(|e| e.to_string())?;
            prop_assert_eq!(got, *want);
            Ok(())
        },
    );
}
