//! Cross-module integration: checker + tuner + swarm over both native
//! models, memory-ceiling fallback, and property plumbing.

use mcautotune::checker::{check, Abort, CheckOptions, StoreKind};
use mcautotune::model::{SafetyLtl, TransitionSystem};
use mcautotune::platform::{
    AbstractModel, DataInit, Granularity, MinModel, PlatformConfig, Tuning,
};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, Method};
use std::time::Duration;

fn swarm_cfg() -> SwarmConfig {
    SwarmConfig { workers: 2, time_budget: Duration::from_secs(5), ..Default::default() }
}

#[test]
fn exhaustive_tuning_matches_ground_truth_across_sizes() {
    for size in [8u32, 16, 32, 64, 128] {
        let m = AbstractModel::new(size, PlatformConfig::default(), Granularity::Phase).unwrap();
        let r = tune(&m, Method::Exhaustive, &CheckOptions::default(), &swarm_cfg(), None).unwrap();
        let (opt_time, _) = m.optimum();
        assert_eq!(r.t_min, opt_time as i64, "size {}", size);
        let w = Tuning { wg: r.optimal.wg, ts: r.optimal.ts };
        assert_eq!(m.predicted_time(w), opt_time, "size {}", size);
    }
}

#[test]
fn swarm_tuning_matches_ground_truth_min_model() {
    for (size, np) in [(16u32, 4u32), (64, 4), (64, 64), (256, 64)] {
        let m = MinModel::paper(size, np).unwrap();
        let r = tune(&m, Method::Swarm, &CheckOptions::default(), &swarm_cfg(), None).unwrap();
        assert_eq!(r.t_min, m.optimum().0 as i64, "size {} np {}", size, np);
    }
}

#[test]
fn tick_and_phase_granularity_tune_to_same_optimum() {
    let plat = PlatformConfig::default();
    let a = AbstractModel::new(32, plat, Granularity::Tick).unwrap();
    let b = AbstractModel::new(32, plat, Granularity::Phase).unwrap();
    let ra = tune(&a, Method::Exhaustive, &CheckOptions::default(), &swarm_cfg(), None).unwrap();
    let rb = tune(&b, Method::Exhaustive, &CheckOptions::default(), &swarm_cfg(), None).unwrap();
    assert_eq!(ra.t_min, rb.t_min);
}

#[test]
fn memory_ceiling_makes_exhaustive_inconclusive_but_swarm_succeeds() {
    // the paper's §5 story: exhaustive verification exceeds RAM, swarm
    // (fixed-size bitstate) still finds the optimum
    let m = AbstractModel::new(256, PlatformConfig::default(), Granularity::Tick).unwrap();
    let mut tight = CheckOptions::default();
    tight.memory_budget = 256 << 10; // 256 KB "machine" for the full store
    let ex = tune(&m, Method::Exhaustive, &tight, &swarm_cfg(), None);
    assert!(ex.is_err(), "exhaustive must report the ceiling, not lie");

    // swarm memory is *fixed* (2 workers x 2 MB bitstate = 4 MB), far
    // below what the full store would need for this state space
    let mut sw = swarm_cfg();
    sw.log2_bits = 24;
    let r = tune(&m, Method::Swarm, &tight, &sw, None).unwrap();
    assert_eq!(r.t_min, m.optimum().0 as i64);
    assert!(r.peak_bytes <= 2 * (1u64 << 24) / 8 + 1024);
}

#[test]
fn over_time_property_boundary_is_exact() {
    // Φo(T_min) must be violated; Φo(T_min - 1) must hold (paper §2)
    let m = MinModel::paper(64, 4).unwrap();
    let (t_min, _) = m.optimum();
    let viol = check(&m, &SafetyLtl::over_time(t_min as i64), &CheckOptions::default()).unwrap();
    assert!(viol.found());
    let hold =
        check(&m, &SafetyLtl::over_time(t_min as i64 - 1), &CheckOptions::default()).unwrap();
    assert!(!hold.found());
    assert!(hold.exhausted);
    assert!(hold.verdict().unwrap());
}

#[test]
fn min_model_result_correct_on_every_explored_path() {
    // data-correctness invariant over the whole state space:
    // whenever FIN holds, the computed minimum equals the true minimum
    for data in [DataInit::Descending, DataInit::Seeded(7)] {
        let m = MinModel::new(64, 4, 3, data, Granularity::Phase).unwrap();
        let prop = SafetyLtl::parse(&format!("G(FIN -> result == {})", m.true_min())).unwrap();
        let rep = check(&m, &prop, &CheckOptions::default()).unwrap();
        assert!(rep.exhausted);
        assert!(!rep.found(), "a FIN state computed a wrong minimum");
    }
}

#[test]
fn store_kinds_agree_on_exhaustive_counts() {
    let m = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
    let p = SafetyLtl::parse("G(true)").unwrap();
    let mut full = CheckOptions::default();
    full.store = StoreKind::Full;
    let mut compact = CheckOptions::default();
    compact.store = StoreKind::HashCompact;
    let rf = check(&m, &p, &full).unwrap();
    let rc = check(&m, &p, &compact).unwrap();
    // hash compaction is collision-free at this scale
    assert_eq!(rf.stats.states_stored, rc.stats.states_stored);
    assert!(rc.stats.bytes_used < rf.stats.bytes_used);
}

#[test]
fn depth_bound_reported_like_spin_m() {
    let m = AbstractModel::new(64, PlatformConfig::default(), Granularity::Tick).unwrap();
    let p = SafetyLtl::parse("G(true)").unwrap();
    let mut o = CheckOptions::default();
    o.max_depth = 100;
    let rep = check(&m, &p, &o).unwrap();
    assert_eq!(rep.stats.abort, Some(Abort::DepthTruncated));
    assert!(rep.stats.max_depth_reached <= 100);
}

#[test]
fn first_trail_is_no_better_than_optimum() {
    for seed in [1u64, 2, 3] {
        let m = MinModel::paper(128, 4).unwrap();
        let mut sw = swarm_cfg();
        sw.seed = seed;
        let r = tune(&m, Method::Swarm, &CheckOptions::default(), &sw, None).unwrap();
        let (w, _) = r.first_trail.unwrap();
        assert!(w.time >= r.t_min);
        let o = r.first_trail_optimality.unwrap();
        assert!(o > 0.0 && o <= 1.0);
    }
}

#[test]
fn eval_var_surface_is_stable() {
    // the tuner contract: models must expose these names
    let a = AbstractModel::new(16, PlatformConfig::default(), Granularity::Phase).unwrap();
    let m = MinModel::paper(16, 4).unwrap();
    let sa = &a.initial_states()[0];
    let sm = &m.initial_states()[0];
    for name in ["time", "FIN", "size"] {
        assert!(a.eval_var(sa, name).is_some(), "abstract lacks {}", name);
        assert!(m.eval_var(sm, name).is_some(), "minimum lacks {}", name);
    }
}
