//! Chaos differential conformance: the task protocol under injected
//! faults must converge to the *same bytes* a fault-free run produces.
//!
//! Faults are injected with `MCAT_FAILPOINTS` (see `util::failpoint`)
//! into real `mcautotune` worker processes: a worker that exits while
//! holding a fresh lease, a shard body that panics, a result publish
//! that fails, a result cache that cannot be saved, a worker killed with
//! SIGTERM mid-drain. The acceptance properties:
//!
//! - crashed/panicked/torn-write schedules recover and the merged report
//!   and cache file are byte-identical to a fault-free single-process
//!   `run_batch` of the same spec;
//! - a deterministically poisoned task is retried exactly
//!   `--max-attempts` times, then dead-lettered; `merge` refuses with a
//!   pointer to `--partial`, and `merge --partial` folds the completed
//!   jobs around it;
//! - a cache-save failure degrades to a report warning instead of
//!   aborting a fully drained batch;
//! - SIGTERM is graceful: current task published, no lease left behind,
//!   exit 0.

use mcautotune::coordinator::{
    run_batch, BatchOptions, BatchReport, ResultCache, TaskDir, TuningJob,
};
use std::path::{Path, PathBuf};
use std::process::Command;
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::Duration;

const BIN: &str = env!("CARGO_BIN_EXE_mcautotune");

fn temp(tag: &str) -> PathBuf {
    static SEQ: AtomicU32 = AtomicU32::new(0);
    std::env::temp_dir().join(format!(
        "mcat_chaos_{}_{}_{}",
        tag,
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// The chaos workload: small enough to drain in well under a second
/// fault-free, sharded enough that faults land mid-batch.
const SPEC: &str = "\
job minimum size=32 np=4 gmt=3 shards=3
job abstract size=16 gmt=10 shards=2
";

fn reference_report(spec: &str, cache_path: &Path) -> BatchReport {
    let jobs = TuningJob::parse_spec(spec).unwrap();
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    let mut cache = ResultCache::open(cache_path).unwrap();
    run_batch(&jobs, &opts, &mut cache).unwrap()
}

/// Every deterministic field of the report (wall-clock fields excluded).
fn assert_reports_identical(single: &BatchReport, multi: &BatchReport) {
    assert_eq!(single.outcomes.len(), multi.outcomes.len());
    for (s, m) in single.outcomes.iter().zip(&multi.outcomes) {
        assert_eq!(s.job, m.job, "job specs must round-trip");
        assert_eq!(s.cached, m.cached, "job `{}`: cached flag", s.job.name);
        assert_eq!(s.shards, m.shards, "job `{}`: shard count", s.job.name);
        assert_eq!(s.result.t_min, m.result.t_min, "job `{}`: verdict", s.job.name);
        let (so, mo) = (&s.result.optimal, &m.result.optimal);
        assert_eq!(
            (so.wg, so.ts, so.time, so.steps),
            (mo.wg, mo.ts, mo.time, mo.steps),
            "job `{}`: best config",
            s.job.name
        );
        assert_eq!(
            s.result.states_explored, m.result.states_explored,
            "job `{}`: states must agree no matter how many retries happened",
            s.job.name
        );
        assert_eq!(s.plan, m.plan, "job `{}`: shard budget plans", s.job.name);
        assert!(!m.lower_bound, "job `{}`: full drains are never lower bounds", s.job.name);
    }
    assert_eq!(single.cache_hits, multi.cache_hits);
    assert_eq!(single.cache_misses, multi.cache_misses);
}

fn assert_cache_files_identical(a: &Path, b: &Path) {
    let a_text = std::fs::read_to_string(a).unwrap();
    let b_text = std::fs::read_to_string(b).unwrap();
    assert_eq!(a_text, b_text, "cache files must be byte-identical");
}

fn run_bin(args: &[&str]) -> String {
    run_bin_env(args, &[])
}

fn run_bin_env(args: &[&str], envs: &[(&str, &str)]) -> String {
    let out = Command::new(BIN)
        .args(args)
        .envs(envs.iter().copied())
        .output()
        .expect("spawn mcautotune");
    assert!(
        out.status.success(),
        "mcautotune {:?} (env {:?}) failed:\nstdout: {}\nstderr: {}",
        args,
        envs,
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

/// Run the binary expecting failure; returns (stdout, stderr).
fn run_bin_expect_failure(args: &[&str], envs: &[(&str, &str)]) -> (String, String) {
    let out = Command::new(BIN)
        .args(args)
        .envs(envs.iter().copied())
        .output()
        .expect("spawn mcautotune");
    assert!(
        !out.status.success(),
        "mcautotune {:?} (env {:?}) unexpectedly succeeded:\n{}",
        args,
        envs,
        String::from_utf8_lossy(&out.stdout)
    );
    (
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

fn plan_only(spec_path: &Path, dir: &Path, cache: &Path, extra: &[&str]) {
    let mut args = vec![
        "batch",
        spec_path.to_str().unwrap(),
        "--task-dir",
        dir.to_str().unwrap(),
        "--plan-only",
        "--cache",
        cache.to_str().unwrap(),
        "--ttl-ms",
        "400",
    ];
    args.extend_from_slice(extra);
    let out = run_bin(&args);
    assert!(out.contains("planned"), "unexpected plan output: {}", out);
}

#[test]
fn injected_crash_panic_and_torn_publish_converge_to_fault_free_bytes() {
    let spec_path = temp("spec");
    std::fs::write(&spec_path, SPEC).unwrap();
    let cache_single = temp("cache_single");
    let cache_multi = temp("cache_multi");
    let dir = temp("tasks");
    let dir_s = dir.to_str().unwrap();

    let single = reference_report(SPEC, &cache_single);
    plan_only(&spec_path, &dir, &cache_multi, &[]);

    // worker 1 dies (exit 86) the instant it wins its first lease: the
    // canonical crashed holder, leaving a fresh never-heartbeated lease
    let out = Command::new(BIN)
        .args(["worker", dir_s, "--poll-ms", "50"])
        .env("MCAT_FAILPOINTS", "task.lease=exit:1")
        .output()
        .expect("spawn crashing worker");
    assert_eq!(out.status.code(), Some(86), "worker must die at the failpoint");
    assert!(
        std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .any(|e| e.file_name().to_string_lossy().ends_with(".lease.json")),
        "the crashed worker must leave its lease behind"
    );

    // worker 2 survives its own faults — one shard body panics, one
    // result publish fails — retries them, reclaims the crashed lease
    // once it goes stale, and drains the batch to completion
    let out = run_bin_env(
        &["worker", dir_s, "--poll-ms", "50"],
        &[("MCAT_FAILPOINTS", "shard.exec=panic:1,task.publish=io-error:1")],
    );
    assert!(out.contains("batch complete"), "chaos worker did not finish: {}", out);
    assert!(
        !out.contains(" 0 reclaimed"),
        "the crashed worker's lease must have been reclaimed: {}",
        out
    );

    // the merged batch is indistinguishable from the fault-free run
    let merge_out = run_bin(&["merge", dir_s]);
    assert!(!merge_out.contains("PARTIAL"), "full drain must not be partial: {}", merge_out);
    let mut cache = ResultCache::open(&cache_multi).unwrap();
    let multi = TaskDir::new(&dir).merge(&mut cache).unwrap();
    assert_reports_identical(&single, &multi);
    assert_cache_files_identical(&cache_single, &cache_multi);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&cache_single).ok();
    std::fs::remove_file(&cache_multi).ok();
}

#[test]
fn poison_task_dead_letters_after_exactly_max_attempts() {
    let spec_path = temp("spec");
    std::fs::write(&spec_path, "job minimum size=16 np=4 gmt=3 shards=1\n").unwrap();
    let cache = temp("cache");
    let dir = temp("tasks");
    let dir_s = dir.to_str().unwrap();
    plan_only(&spec_path, &dir, &cache, &["--max-attempts", "3"]);

    // an uncounted panic failpoint poisons every execution of the only
    // task; a single worker retries it through the attempt budget (the
    // backoff between attempts defers leases, so the drain loop must
    // wait it out) and dead-letters it — at which point the batch
    // counts as drained
    let out = run_bin_env(
        &["worker", dir_s, "--poll-ms", "50"],
        &[("MCAT_FAILPOINTS", "shard.exec=panic")],
    );
    assert!(
        out.contains("drained 3 task(s)"),
        "a poisoned task must be attempted exactly --max-attempts times: {}",
        out
    );
    assert!(out.contains("batch complete"), "dead-lettering must unblock the drain: {}", out);

    // the dead-letter record captures the attempt count and the panic
    let id = "j000-s000";
    let text = std::fs::read_to_string(dir.join("dead").join(format!("{}.json", id)))
        .unwrap_or_else(|e| panic!("dead/{}.json must exist: {}", id, e));
    let dead = mcautotune::util::manifest::Json::parse(&text).unwrap();
    assert_eq!(dead.get("attempts").and_then(|v| v.as_i64()), Some(3), "{}", text);
    let err = dead.get("dead_error").and_then(|v| v.as_str()).unwrap_or_default();
    assert!(err.contains("panic"), "the captured failure names the panic: {}", text);
    assert!(
        !dir.join(format!("{}.task.json", id)).exists()
            && !dir.join(format!("{}.lease.json", id)).exists(),
        "a dead task must leave no task/lease file"
    );
    let st = TaskDir::new(&dir).status().unwrap();
    assert_eq!(st.dead.len(), 1, "status surfaces the dead letter: {:?}", st.dead);

    // strict merge refuses and points at the escape hatch; --partial
    // folds around the dead task without aborting
    let (_, stderr) = run_bin_expect_failure(&["merge", dir_s], &[]);
    assert!(stderr.contains("dead-lettered"), "strict merge must name the cause: {}", stderr);
    assert!(stderr.contains("--partial"), "strict merge must point at --partial: {}", stderr);
    let out = run_bin(&["merge", dir_s, "--partial"]);
    assert!(out.contains("dead-lettered task(s):"), "partial merge reports: {}", out);
    assert!(out.contains("PARTIAL (1 dead, 0 pending)"), "{}", out);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&cache).ok();
}

#[test]
fn cache_save_failure_degrades_to_a_warning() {
    let spec_path = temp("spec");
    std::fs::write(&spec_path, "job minimum size=16 np=4 gmt=3 shards=1\n").unwrap();
    let cache = temp("cache");
    // in-process batch: all shards run, then the cache save fails — the
    // report (with results) must still print, with a warning, exit 0
    let out = run_bin_env(
        &[
            "batch",
            spec_path.to_str().unwrap(),
            "--cache",
            cache.to_str().unwrap(),
        ],
        &[("MCAT_FAILPOINTS", "cache.save=io-error")],
    );
    assert!(out.contains("minimum-16"), "results must still be reported: {}", out);
    assert!(
        out.contains("warning: result cache not saved"),
        "save failure must surface as a warning: {}",
        out
    );
    assert!(!cache.exists(), "the injected fault must have prevented the save");

    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&cache).ok();
}

#[test]
#[cfg(unix)]
fn sigterm_mid_drain_is_graceful_and_leaves_no_lease() {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGTERM: i32 = 15;

    let spec_path = temp("spec");
    std::fs::write(&spec_path, SPEC).unwrap();
    let cache = temp("cache");
    let dir = temp("tasks");
    let dir_s = dir.to_str().unwrap();
    plan_only(&spec_path, &dir, &cache, &[]);

    // every shard body sleeps 100ms first (delay failpoint), so the
    // 5-task drain is guaranteed to still be running when SIGTERM lands
    let mut worker = Command::new(BIN)
        .args(["worker", dir_s, "--poll-ms", "50"])
        .env("MCAT_FAILPOINTS", "shard.exec=delay")
        .stdout(std::process::Stdio::piped())
        .spawn()
        .expect("spawn worker");
    std::thread::sleep(Duration::from_millis(150));
    assert_eq!(unsafe { kill(worker.id() as i32, SIGTERM) }, 0, "kill(2) failed");
    let out = worker.wait_with_output().expect("worker wait");
    assert!(
        out.status.success(),
        "SIGTERM must exit 0, got {:?}: {}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("SIGTERM"), "worker must report the graceful exit: {}", stdout);

    // the in-flight task was finished and published; no lease remains
    assert!(
        !std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .any(|e| e.file_name().to_string_lossy().ends_with(".lease.json")),
        "a graceful exit must hold no leases"
    );

    // the rest of the fleet finishes the batch and the merge is whole
    let out = run_bin(&["worker", dir_s, "--poll-ms", "50"]);
    assert!(out.contains("batch complete"), "{}", out);
    let merge_out = run_bin(&["merge", dir_s]);
    assert!(!merge_out.contains("PARTIAL"), "{}", merge_out);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&spec_path).ok();
    std::fs::remove_file(&cache).ok();
}
