//! Parallel-vs-sequential engine equivalence and compiled-evaluator
//! equivalence (ISSUE 2 acceptance criteria).
//!
//! The parallel engine must report the same `states_stored`,
//! violations-found verdict and `exhausted` flag as the sequential DFS on
//! every deterministic model; the compiled property evaluator must agree
//! with the interpreted `Expr::eval` on a generated expression corpus,
//! including error cases (unknown variables, division by zero).

use mcautotune::checker::{
    check, check_parallel, check_sequential, Abort, CheckOptions, Frontier, Order, StoreKind,
};
use mcautotune::model::{EvalScratch, SafetyLtl, TransitionSystem};
use mcautotune::platform::{AbstractModel, Granularity, MinModel, PlatformConfig};
use mcautotune::util::rng::Xoshiro256;

// ------------------------------------------------------------ test models --

/// Binary tree of depth `d` (wide state space, good parallel fan-out),
/// exposing its variables through the native slot interface.
struct Tree {
    depth: u32,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct TState {
    level: u32,
    path: u32,
}

impl TransitionSystem for Tree {
    type State = TState;

    fn initial_states(&self) -> Vec<TState> {
        vec![TState { level: 0, path: 0 }]
    }

    fn successors(&self, s: &TState, out: &mut Vec<TState>) {
        out.clear();
        if s.level < self.depth {
            out.push(TState { level: s.level + 1, path: s.path << 1 });
            out.push(TState { level: s.level + 1, path: (s.path << 1) | 1 });
        }
    }

    fn encode(&self, s: &TState, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&s.level.to_le_bytes());
        out.extend_from_slice(&s.path.to_le_bytes());
    }

    fn eval_var(&self, s: &TState, name: &str) -> Option<i64> {
        match name {
            "level" => Some(s.level as i64),
            "path" => Some(s.path as i64),
            "leaf" => Some((s.level == self.depth) as i64),
            _ => None,
        }
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        ["level", "path", "leaf"].iter().position(|n| *n == name).map(|i| i as u32)
    }

    fn eval_slots(&self, s: &TState, ids: &[u32], out: &mut [i64]) -> u64 {
        for (i, &id) in ids.iter().enumerate() {
            out[i] = match id {
                0 => s.level as i64,
                1 => s.path as i64,
                _ => (s.level == self.depth) as i64,
            };
        }
        0
    }
}

fn popts(threads: u32) -> CheckOptions {
    CheckOptions { threads, ..CheckOptions::default() }
}

fn assert_reports_match<S, T>(
    seq: &mcautotune::checker::CheckReport<S>,
    par: &mcautotune::checker::CheckReport<T>,
) {
    assert_eq!(par.stats.states_stored, seq.stats.states_stored, "states_stored");
    assert_eq!(par.stats.states_matched, seq.stats.states_matched, "states_matched");
    assert_eq!(par.stats.transitions, seq.stats.transitions, "transitions");
    assert_eq!(par.exhausted, seq.exhausted, "exhausted");
    assert_eq!(par.found(), seq.found(), "found");
}

// --------------------------------------------- parallel == sequential --

#[test]
fn tree_parallel_matches_sequential() {
    let m = Tree { depth: 12 };
    let p = SafetyLtl::parse("G(level >= 0)").unwrap();
    let seq = check_sequential(&m, &p, &CheckOptions::default()).unwrap();
    for threads in [2, 4] {
        let par = check_parallel(&m, &p, &popts(threads)).unwrap();
        assert_reports_match(&seq, &par);
        assert_eq!(par.stats.states_stored, (1u64 << 13) - 1);
        assert!(par.verdict().unwrap());
    }
}

#[test]
fn minmodel_parallel_matches_sequential() {
    let m = MinModel::paper(64, 4).unwrap();
    // the checker proves the data invariant over every schedule
    let p = SafetyLtl::parse("G(FIN -> result == 1)").unwrap();
    let seq = check_sequential(&m, &p, &CheckOptions::default()).unwrap();
    let par = check_parallel(&m, &p, &popts(4)).unwrap();
    assert_reports_match(&seq, &par);
    assert!(par.verdict().unwrap());
}

#[test]
fn abstract_parallel_matches_sequential_collect_all() {
    let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
    let p = SafetyLtl::non_termination();
    let mut o = popts(4);
    o.collect_all = true;
    let so = CheckOptions { collect_all: true, ..CheckOptions::default() };
    let seq = check_sequential(&m, &p, &so).unwrap();
    let par = check_parallel(&m, &p, &o).unwrap();
    assert_reports_match(&seq, &par);
    // one FIN state per tuning, found by both engines
    assert_eq!(par.violations.len(), seq.violations.len());
    assert_eq!(par.violations.len(), m.tunings().len());
    assert!(par.exhausted);
}

#[test]
fn abstract_parallel_verdict_on_violated_property() {
    let m = AbstractModel::new(32, PlatformConfig::default(), Granularity::Phase).unwrap();
    let (opt_time, _) = m.optimum();
    let p = SafetyLtl::over_time(opt_time as i64);
    let seq = check_sequential(&m, &p, &CheckOptions::default()).unwrap();
    let par = check_parallel(&m, &p, &popts(4)).unwrap();
    assert!(!seq.verdict().unwrap());
    assert!(!par.verdict().unwrap());
    assert!(!par.exhausted);
    // the violating state exposes a real tuning at a real time
    let v = &par.violations[0];
    assert!(v.trail.final_var(&m, "WG").is_some());
    assert_eq!(v.trail.final_var(&m, "FIN"), Some(1));
}

#[test]
fn hashcompact_parallel_matches_sequential() {
    let m = Tree { depth: 12 };
    let p = SafetyLtl::parse("G(true)").unwrap();
    let so = CheckOptions { store: StoreKind::HashCompact, ..CheckOptions::default() };
    let mut po = popts(4);
    po.store = StoreKind::HashCompact;
    let seq = check_sequential(&m, &p, &so).unwrap();
    let par = check_parallel(&m, &p, &po).unwrap();
    assert_reports_match(&seq, &par);
}

#[test]
fn parallel_trail_is_a_valid_parent_chain() {
    let m = Tree { depth: 8 };
    let p = SafetyLtl::parse("G(leaf -> path != 37)").unwrap();
    let par = check_parallel(&m, &p, &popts(4)).unwrap();
    assert!(par.found());
    assert_eq!(par.violations.len(), 1, "first-violation mode returns one trail");
    let v = &par.violations[0];
    assert_eq!(v.trail.steps(), 8, "trail reconstructed back to the root");
    assert_eq!(v.trail.final_var(&m, "path"), Some(37));
    for w in v.trail.states.windows(2) {
        assert_eq!(w[1].level, w[0].level + 1);
        assert_eq!(w[1].path >> 1, w[0].path);
    }
}

#[test]
fn parallel_collect_all_trails_are_valid() {
    let m = Tree { depth: 6 };
    let p = SafetyLtl::parse("G(!leaf)").unwrap();
    let mut o = popts(4);
    o.collect_all = true;
    let par = check_parallel(&m, &p, &o).unwrap();
    assert_eq!(par.violations.len(), 64);
    assert!(par.exhausted);
    for v in &par.violations {
        assert_eq!(v.trail.steps(), 6);
        for w in v.trail.states.windows(2) {
            assert_eq!(w[1].level, w[0].level + 1);
            assert_eq!(w[1].path >> 1, w[0].path);
        }
    }
}

#[test]
fn parallel_budget_abort_is_inconclusive() {
    let m = Tree { depth: 22 };
    let p = SafetyLtl::parse("G(true)").unwrap();
    let mut o = popts(4);
    o.max_states = 5_000;
    let r = check_parallel(&m, &p, &o).unwrap();
    assert_eq!(r.stats.abort, Some(Abort::StateLimit));
    assert!(!r.exhausted);
    assert!(r.verdict().is_err());
}

#[test]
fn parallel_max_errors_caps_violations() {
    let m = Tree { depth: 6 };
    let p = SafetyLtl::parse("G(!leaf)").unwrap();
    let mut o = popts(4);
    o.collect_all = true;
    o.max_errors = 10;
    let r = check_parallel(&m, &p, &o).unwrap();
    assert!(r.violations.len() <= 10);
    assert!(!r.violations.is_empty());
    assert_eq!(r.stats.abort, Some(Abort::ErrorLimit));
    assert!(!r.exhausted);
}

#[test]
fn dispatcher_routes_on_threads_and_store() {
    let m = Tree { depth: 10 };
    let p = SafetyLtl::parse("G(true)").unwrap();
    // threads=4 exact store: parallel path, same count
    let r = check(&m, &p, &popts(4)).unwrap();
    assert_eq!(r.stats.states_stored, 2047);
    assert!(r.exhausted);
    // threads=0 resolves to all cores
    let r = check(&m, &p, &popts(0)).unwrap();
    assert_eq!(r.stats.states_stored, 2047);
    // bitstate + threads>1 falls back to the sequential engine (partial)
    let mut o = popts(4);
    o.store = StoreKind::Bitstate { log2_bits: 20, hashes: 3 };
    let r = check(&m, &p, &o).unwrap();
    assert!(!r.exhausted);
}

#[test]
fn parallel_unknown_variable_errors_like_sequential() {
    let m = Tree { depth: 4 };
    let p = SafetyLtl::parse("G(nosuchvar > 0)").unwrap();
    assert!(check_sequential(&m, &p, &CheckOptions::default()).is_err());
    assert!(check_parallel(&m, &p, &popts(4)).is_err());
}

// ------------------------------------------- deterministic frontier --

fn dopts(threads: u32) -> CheckOptions {
    CheckOptions { threads, frontier: Frontier::Deterministic, ..CheckOptions::default() }
}

#[test]
fn deterministic_frontier_matches_sequential_on_full_exploration() {
    let m = Tree { depth: 12 };
    let p = SafetyLtl::parse("G(level >= 0)").unwrap();
    let seq = check_sequential(&m, &p, &CheckOptions::default()).unwrap();
    for threads in [1, 2, 4] {
        let det = check_parallel(&m, &p, &dopts(threads)).unwrap();
        assert_reports_match(&seq, &det);
        assert!(det.verdict().unwrap());
    }
}

#[test]
fn deterministic_frontier_is_reproducible_across_runs_and_thread_counts() {
    // with Order::Random the async engine's first violation depends on
    // scheduling; the deterministic frontier must pin the full violation
    // sequence — across repeated runs AND across thread counts
    let m = Tree { depth: 10 };
    let p = SafetyLtl::parse("G(!leaf)").unwrap();
    let run = |threads: u32| -> Vec<i64> {
        let mut o = dopts(threads);
        o.order = Order::Random(0xD5EED);
        o.collect_all = true;
        let r = check_parallel(&m, &p, &o).unwrap();
        assert_eq!(r.violations.len(), 1024);
        r.violations.iter().map(|v| v.trail.final_var(&m, "path").unwrap()).collect()
    };
    let baseline = run(4);
    assert_eq!(run(4), baseline, "same thread count must reproduce exactly");
    assert_eq!(run(2), baseline, "thread count must not change the order");
    assert_eq!(run(1), baseline);
    // the shuffle actually diversifies (it is not secretly in-order)
    let mut o = dopts(4);
    o.collect_all = true;
    let inorder = check_parallel(&m, &p, &o).unwrap();
    let inorder_paths: Vec<i64> =
        inorder.violations.iter().map(|v| v.trail.final_var(&m, "path").unwrap()).collect();
    assert_ne!(inorder_paths, baseline, "Random order should differ from InOrder");
}

#[test]
fn deterministic_frontier_first_trail_is_stable() {
    let m = Tree { depth: 10 };
    let p = SafetyLtl::parse("G(!leaf)").unwrap();
    let first = |threads: u32| {
        let mut o = dopts(threads);
        o.order = Order::Random(7);
        let r = check_parallel(&m, &p, &o).unwrap();
        assert_eq!(r.violations.len(), 1, "first-violation mode");
        assert!(!r.exhausted);
        (r.violations[0].trail.final_var(&m, "path").unwrap(), r.stats.states_stored)
    };
    let (path, stored) = first(4);
    for _ in 0..3 {
        assert_eq!(first(4), (path, stored));
    }
    assert_eq!(first(2), (path, stored), "early-stop state count is thread-independent");
}

#[test]
fn deterministic_frontier_trails_and_budgets() {
    // trails are valid parent chains, and deterministic aborts fire at
    // exactly the configured threshold
    let m = Tree { depth: 8 };
    let p = SafetyLtl::parse("G(leaf -> path != 37)").unwrap();
    let r = check_parallel(&m, &p, &dopts(4)).unwrap();
    assert!(r.found());
    let v = &r.violations[0];
    assert_eq!(v.trail.steps(), 8);
    assert_eq!(v.trail.final_var(&m, "path"), Some(37));
    for w in v.trail.states.windows(2) {
        assert_eq!(w[1].level, w[0].level + 1);
        assert_eq!(w[1].path >> 1, w[0].path);
    }

    let big = Tree { depth: 20 };
    let q = SafetyLtl::parse("G(true)").unwrap();
    let mut o = dopts(4);
    o.max_states = 5_000;
    let a = check_parallel(&big, &q, &o).unwrap();
    let b = check_parallel(&big, &q, &o).unwrap();
    assert_eq!(a.stats.abort, Some(Abort::StateLimit));
    assert_eq!(a.stats.states_stored, 5_000, "deterministic abort at the exact threshold");
    assert_eq!(b.stats.states_stored, 5_000);
    assert!(a.verdict().is_err());

    // error limit, deterministically
    let m6 = Tree { depth: 6 };
    let leafy = SafetyLtl::parse("G(!leaf)").unwrap();
    let mut o = dopts(4);
    o.collect_all = true;
    o.max_errors = 10;
    let r = check_parallel(&m6, &leafy, &o).unwrap();
    assert_eq!(r.violations.len(), 10);
    assert_eq!(r.stats.abort, Some(Abort::ErrorLimit));
}

#[test]
fn deterministic_frontier_memory_abort_is_thread_independent() {
    // the hash-prefix-sharded dedup pass uses a fixed shard count, so
    // store capacities — and the level at which the budget trips — must
    // not depend on how many workers scanned the shards
    let m = Tree { depth: 16 };
    let p = SafetyLtl::parse("G(true)").unwrap();
    let run = |threads: u32| {
        let mut o = dopts(threads);
        o.memory_budget = 256 * 1024;
        let r = check_parallel(&m, &p, &o).unwrap();
        assert_eq!(r.stats.abort, Some(Abort::MemoryLimit));
        assert!(!r.exhausted);
        r.stats.states_stored
    };
    let four = run(4);
    assert_eq!(run(2), four, "abort point is thread-count-independent");
    assert_eq!(run(1), four);
}

#[test]
fn deterministic_frontier_por_is_reproducible_across_thread_counts() {
    // --por on the det frontier: ample selection is a pure function of
    // the state, so the reduced exploration — counts AND the violation
    // sequence — must be byte-stable across thread counts
    let src = mcautotune::promela::templates::minimum_pml(8, 4, 3);
    let p = SafetyLtl::parse("G(!FIN)").unwrap();
    let run = |threads: u32| {
        let m = mcautotune::promela::PromelaVm::from_source(&src).unwrap();
        let mut o = dopts(threads);
        o.por = true;
        o.collect_all = true;
        let r = check_parallel(&m, &p, &o).unwrap();
        assert!(r.found());
        let times: Vec<i64> =
            r.violations.iter().map(|v| v.trail.final_var(&m, "time").unwrap()).collect();
        (r.stats.states_stored, r.stats.transitions, times)
    };
    let four = run(4);
    assert_eq!(run(2), four, "por reduction is thread-count-independent");
    assert_eq!(run(1), four);
}

#[test]
fn deterministic_frontier_on_minmodel_matches_sequential() {
    let m = MinModel::paper(64, 4).unwrap();
    let p = SafetyLtl::parse("G(FIN -> result == 1)").unwrap();
    let seq = check_sequential(&m, &p, &CheckOptions::default()).unwrap();
    let det = check_parallel(&m, &p, &dopts(3)).unwrap();
    assert_reports_match(&seq, &det);
    assert!(det.verdict().unwrap());
}

#[test]
fn dispatcher_routes_deterministic_even_single_threaded() {
    // Frontier::Deterministic pins the exploration order regardless of
    // thread count, so check() must route it to the parallel module even
    // at threads=1 (BFS, not the DFS fallback)
    let m = Tree { depth: 6 };
    let p = SafetyLtl::parse("G(!leaf)").unwrap();
    let mut o = dopts(1);
    o.order = Order::Random(99);
    o.collect_all = true;
    let one = check(&m, &p, &o).unwrap();
    let mut o4 = o.clone();
    o4.threads = 4;
    let four = check(&m, &p, &o4).unwrap();
    let paths = |r: &mcautotune::checker::CheckReport<_>| -> Vec<i64> {
        r.violations.iter().map(|v| v.trail.final_var(&m, "path").unwrap()).collect()
    };
    assert_eq!(paths(&one), paths(&four));
}

// --------------------------------------------------- store pre-sizing --

#[test]
fn presized_stores_do_not_change_results() {
    let m = Tree { depth: 12 };
    let p = SafetyLtl::parse("G(level >= 0)").unwrap();
    let baseline = check_sequential(&m, &p, &CheckOptions::default()).unwrap();
    for estimate in [1u64, 8_191, 1 << 13, 1 << 20] {
        // sequential, async parallel, deterministic parallel — all presized
        let mut o = CheckOptions::default();
        o.expected_states = estimate;
        let seq = check_sequential(&m, &p, &o).unwrap();
        assert_reports_match(&baseline, &seq);
        o.threads = 4;
        let par = check_parallel(&m, &p, &o).unwrap();
        assert_reports_match(&baseline, &par);
        o.frontier = Frontier::Deterministic;
        let det = check_parallel(&m, &p, &o).unwrap();
        assert_reports_match(&baseline, &det);
    }
}

// ------------------------------------------- evaluator equivalence --

/// Single-state model exposing an environment by name only (the compiled
/// evaluator's fallback path).
struct EnvModel {
    pairs: Vec<(&'static str, i64)>,
}

impl TransitionSystem for EnvModel {
    type State = u8;

    fn initial_states(&self) -> Vec<u8> {
        vec![0]
    }

    fn successors(&self, _s: &u8, out: &mut Vec<u8>) {
        out.clear();
    }

    fn encode(&self, s: &u8, out: &mut Vec<u8>) {
        out.clear();
        out.push(*s);
    }

    fn eval_var(&self, _s: &u8, name: &str) -> Option<i64> {
        self.pairs.iter().find(|(k, _)| *k == name).map(|(_, v)| *v)
    }
}

/// Same environment through the native slot interface.
struct SlotEnvModel {
    pairs: Vec<(&'static str, i64)>,
}

impl TransitionSystem for SlotEnvModel {
    type State = u8;

    fn initial_states(&self) -> Vec<u8> {
        vec![0]
    }

    fn successors(&self, _s: &u8, out: &mut Vec<u8>) {
        out.clear();
    }

    fn encode(&self, s: &u8, out: &mut Vec<u8>) {
        out.clear();
        out.push(*s);
    }

    fn eval_var(&self, _s: &u8, name: &str) -> Option<i64> {
        self.pairs.iter().find(|(k, _)| *k == name).map(|(_, v)| *v)
    }

    fn resolve_slot(&self, name: &str) -> Option<u32> {
        self.pairs.iter().position(|(k, _)| *k == name).map(|i| i as u32)
    }

    fn eval_slots(&self, _s: &u8, ids: &[u32], out: &mut [i64]) -> u64 {
        for (i, &id) in ids.iter().enumerate() {
            out[i] = self.pairs[id as usize].1;
        }
        0
    }
}

/// Random expression source over known vars (a, b, c), the occasionally
/// unknown `q`, and integer literals (including 0, so `/` and `%` exercise
/// the error paths).
fn gen_expr(r: &mut Xoshiro256, depth: u32) -> String {
    if depth == 0 || r.chance(1, 3) {
        return match r.below(3) {
            0 => (*r.pick(&["a", "b", "c", "a", "b", "c", "q"])).to_string(),
            1 => r.range_i64(-4, 4).to_string(),
            _ => (*r.pick(&["true", "false"])).to_string(),
        };
    }
    match r.below(17) {
        0 => format!("(!{})", gen_expr(r, depth - 1)),
        1 => format!("(-{})", gen_expr(r, depth - 1)),
        n => {
            let op = ["&&", "||", "->", "==", "!=", "<", "<=", ">", ">=", "+", "-", "*", "/", "%"]
                [(n - 2) as usize % 14];
            format!("({} {} {})", gen_expr(r, depth - 1), op, gen_expr(r, depth - 1))
        }
    }
}

#[test]
fn compiled_evaluator_matches_interpreter_on_generated_corpus() {
    let mut r = Xoshiro256::new(0xC0FFEE);
    let mut scratch = EvalScratch::default();
    let mut err_cases = 0u32;
    let mut unknown_cases = 0u32;
    for case in 0..500 {
        let src = gen_expr(&mut r, 4);
        let env = [("a", r.range_i64(-6, 6)), ("b", r.range_i64(-6, 6)), ("c", r.range_i64(-6, 6))];
        let Ok(p) = SafetyLtl::parse(&src) else {
            panic!("generated expression failed to parse: {}", src);
        };
        if src.contains('q') {
            unknown_cases += 1;
        }
        let lookup = |n: &str| env.iter().find(|(k, _)| *k == n).map(|(_, v)| *v);
        let interp = p.body.eval(&lookup);

        let fallback = EnvModel { pairs: env.to_vec() };
        let slotted = SlotEnvModel { pairs: env.to_vec() };
        let c_fb = p.compile(&fallback).unwrap();
        let c_sl = p.compile(&slotted).unwrap();
        let got_fb = c_fb.eval_state(&fallback, &0, &mut scratch);
        let got_sl = c_sl.eval_state(&slotted, &0, &mut scratch);

        match interp {
            Ok(v) => {
                assert_eq!(got_fb.as_ref().ok(), Some(&v), "case {}: `{}` fallback", case, src);
                assert_eq!(got_sl.as_ref().ok(), Some(&v), "case {}: `{}` slotted", case, src);
            }
            Err(_) => {
                err_cases += 1;
                assert!(got_fb.is_err(), "case {}: `{}` should error (fallback)", case, src);
                assert!(got_sl.is_err(), "case {}: `{}` should error (slotted)", case, src);
            }
        }
    }
    // the corpus must actually exercise the interesting regions
    assert!(err_cases > 10, "too few error cases generated ({})", err_cases);
    assert!(unknown_cases > 10, "too few unknown-variable cases ({})", unknown_cases);
}

#[test]
fn compiled_evaluator_agrees_inside_the_checker() {
    // same property, interpreted via eval_var vs checked end-to-end: the
    // check() verdict must match a brute-force interpreted sweep
    let m = Tree { depth: 9 };
    for src in ["G(leaf -> path != 100)", "G(path % 7 != 6 || level < 20)", "G(level <= 9)"] {
        let p = SafetyLtl::parse(src).unwrap();
        let seq = check_sequential(&m, &p, &CheckOptions::default()).unwrap();
        let par = check_parallel(&m, &p, &popts(4)).unwrap();
        assert_eq!(seq.found(), par.found(), "{}", src);
    }
}
