//! Coordinator integration: sharded batch orchestration, the
//! work-stealing queue, and the persistent result cache — including the
//! acceptance properties (batch optima match single-job `tune`; a second
//! invocation serves cache hits with zero additional states explored;
//! Promela-engine batch jobs match `tune --engine promela`; shard budgets
//! scale with estimated sub-lattice size).

use mcautotune::checker::CheckOptions;
use mcautotune::coordinator::{
    partition, run_batch, BatchOptions, JobEngine, JobQueue, ModelKind, ResultCache, ShardModel,
    TuningJob,
};
use mcautotune::platform::MinModel;
use mcautotune::promela::{templates, PromelaSystem};
use mcautotune::swarm::SwarmConfig;
use mcautotune::tuner::{tune, tune_cached, Method};
use std::path::PathBuf;

fn temp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mcat_coord_{}_{}.json", tag, std::process::id()))
}

#[test]
fn cache_hit_returns_identical_result_with_zero_states() {
    let m = MinModel::paper(64, 4).unwrap();
    let mut cache = ResultCache::in_memory();
    let desc = TuningJob::new(ModelKind::Minimum, 64).cache_desc();
    let (cold, was_hit) = tune_cached(
        &m,
        Method::Exhaustive,
        &CheckOptions::default(),
        &SwarmConfig::default(),
        None,
        &desc,
        &mut cache,
    )
    .unwrap();
    assert!(!was_hit);
    assert!(cold.states_explored > 0);

    let (warm, was_hit) = tune_cached(
        &m,
        Method::Exhaustive,
        &CheckOptions::default(),
        &SwarmConfig::default(),
        None,
        &desc,
        &mut cache,
    )
    .unwrap();
    assert!(was_hit);
    assert_eq!(warm.states_explored, 0, "a hit must not explore any state");
    assert_eq!(warm.peak_bytes, 0);
    assert_eq!(
        (warm.optimal.wg, warm.optimal.ts, warm.t_min, warm.optimal.steps),
        (cold.optimal.wg, cold.optimal.ts, cold.t_min, cold.optimal.steps),
        "hit and cold run must agree on the optimum"
    );
    assert_eq!((cache.hits, cache.misses), (1, 1));
}

#[test]
fn sharded_search_agrees_with_exhaustive_optimum() {
    // satellite requirement: sharded search == Method::Exhaustive optimum
    // on MinModel::paper(64, 4)
    let m = MinModel::paper(64, 4).unwrap();
    let (opt_time, _) = m.optimum();
    let unsharded = tune(
        &m,
        Method::Exhaustive,
        &CheckOptions::default(),
        &SwarmConfig::default(),
        None,
    )
    .unwrap();
    assert_eq!(unsharded.t_min, opt_time as i64);

    let shards = partition(m.tunings(), 4);
    assert!(shards.len() >= 2, "64-element lattice must split: {:?}", shards);
    let mut best = i64::MAX;
    for &shard in &shards {
        let sharded = ShardModel::new(&m, shard);
        let r = tune(
            &sharded,
            Method::Exhaustive,
            &CheckOptions::default(),
            &SwarmConfig::default(),
            None,
        )
        .unwrap();
        best = best.min(r.t_min);
    }
    assert_eq!(best, unsharded.t_min, "merged shard optimum == unsharded optimum");
}

#[test]
fn queue_drains_under_one_worker() {
    let q = JobQueue::new(1);
    let (out, stats) = q.run_stats((0..64u64).collect(), |x| x + 1);
    assert_eq!(out, (1..=64).collect::<Vec<_>>());
    assert_eq!(stats.executed, vec![64], "one worker executes every task");
    assert_eq!(stats.stolen, 0);
}

#[test]
fn batch_matches_single_job_tune_and_second_run_hits_cache() {
    let path = temp_path("batch");
    std::fs::remove_file(&path).ok();

    let jobs = TuningJob::parse_spec(
        "job minimum size=64 np=4 gmt=3 shards=4\n\
         job minimum size=32 np=4 gmt=3\n\
         job abstract size=16 gmt=10 shards=2\n",
    )
    .unwrap();
    assert_eq!(jobs.len(), 3);
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };

    // cold run: everything misses, optima match the ground truth
    let mut cache = ResultCache::open(&path).unwrap();
    let report = run_batch(&jobs, &opts, &mut cache).unwrap();
    assert_eq!(report.outcomes.len(), 3);
    assert_eq!((report.cache_hits, report.cache_misses), (0, 3));
    assert!(report.total_states() > 0);
    for outcome in &report.outcomes {
        assert!(!outcome.cached);
        assert!(outcome.shards >= 1);
        assert_eq!(
            outcome.result.t_min,
            outcome.job.optimum_time().unwrap() as i64,
            "job `{}` batch optimum != model optimum",
            outcome.job.name
        );
    }
    let rendered = report.render();
    assert!(rendered.contains("minimum-64") && rendered.contains("miss"));

    // warm run from a fresh cache object (exercises the JSON reload):
    // every job hits, zero additional states explored
    let mut cache2 = ResultCache::open(&path).unwrap();
    assert_eq!(cache2.len(), 3);
    let report2 = run_batch(&jobs, &opts, &mut cache2).unwrap();
    assert_eq!((report2.cache_hits, report2.cache_misses), (3, 0));
    assert_eq!(report2.total_states(), 0, "cached batch explores zero states");
    for (cold, warm) in report.outcomes.iter().zip(&report2.outcomes) {
        assert!(warm.cached);
        assert_eq!(warm.result.t_min, cold.result.t_min);
        assert_eq!(warm.result.optimal.wg, cold.result.optimal.wg);
        assert_eq!(warm.result.optimal.ts, cold.result.optimal.ts);
        assert_eq!(warm.result.states_explored, 0);
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn overlapping_jobs_in_one_batch_run_once() {
    // two jobs with the same cache description: the second resolves from
    // the first's freshly stored result
    let jobs = vec![
        TuningJob::new(ModelKind::Minimum, 32),
        TuningJob { name: "same-again".into(), ..TuningJob::new(ModelKind::Minimum, 32) },
    ];
    assert_eq!(jobs[0].cache_desc(), jobs[1].cache_desc());
    let mut cache = ResultCache::in_memory();
    let report =
        run_batch(&jobs, &BatchOptions { workers: 2, ..BatchOptions::default() }, &mut cache)
            .unwrap();
    assert!(!report.outcomes[0].cached);
    assert!(report.outcomes[1].cached, "duplicate must be served from the batch's own result");
    assert_eq!(report.outcomes[1].result.states_explored, 0);
    assert_eq!(report.outcomes[0].result.t_min, report.outcomes[1].result.t_min);
    // both submission lookups missed; the duplicate's resolution hit
    assert_eq!((report.cache_hits, report.cache_misses), (1, 2));
}

#[test]
fn failing_job_does_not_discard_completed_work() {
    use mcautotune::tuner::TuneCache;
    let good = TuningJob::new(ModelKind::Minimum, 32);
    let mut bad = TuningJob::new(ModelKind::Minimum, 64);
    bad.method = Method::Swarm;
    let mut opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    // depth bound 1: swarm workers can never reach FIN, so the swarm job
    // deterministically fails while the exhaustive job succeeds
    opts.swarm.max_depth = 1;
    let mut cache = ResultCache::in_memory();
    let err = run_batch(&[good.clone(), bad], &opts, &mut cache).unwrap_err();
    let msg = format!("{:#}", err);
    assert!(msg.contains("shard failed"), "unexpected error: {}", msg);
    // the completed job's result was still merged and cached
    assert_eq!(cache.len(), 1);
    assert!(cache.lookup(&good.cache_desc()).is_some());
}

#[test]
fn promela_batch_job_matches_native_job_and_single_shot_tune() {
    // ISSUE 3 acceptance: a batch draining one `engine: promela` job and
    // one native job produces a merged report whose Promela-job optimum
    // matches `tune --engine promela` on the same model
    let (size, np, gmt) = (16u32, 4u32, 3u32);
    let spec = format!(
        "job minimum size={s} np={np} gmt={g} engine=promela shards=2 name=pml\n\
         job minimum size={s} np={np} gmt={g} name=native\n",
        s = size,
        np = np,
        g = gmt
    );
    let jobs = TuningJob::parse_spec(&spec).unwrap();
    assert_eq!(jobs[0].engine, JobEngine::Promela);
    assert_ne!(
        jobs[0].cache_desc(),
        jobs[1].cache_desc(),
        "promela and native runs of the same model are distinct cache entries"
    );
    let mut cache = ResultCache::in_memory();
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    let report = run_batch(&jobs, &opts, &mut cache).unwrap();

    // single-shot tune through the Promela engine (the CLI's
    // `tune --engine promela` path)
    let sys = PromelaSystem::from_source(&templates::minimum_pml(size, np, gmt)).unwrap();
    let single = tune(
        &sys,
        Method::Exhaustive,
        &CheckOptions::default(),
        &SwarmConfig::default(),
        Some(10_000),
    )
    .unwrap();

    let pml = &report.outcomes[0];
    let native = &report.outcomes[1];
    assert_eq!(pml.result.t_min, single.t_min, "batched == single-shot Promela optimum");
    assert_eq!(pml.result.t_min, native.result.t_min, "promela == native optimum");
    assert_eq!(
        (pml.result.optimal.wg, pml.result.optimal.ts),
        (native.result.optimal.wg, native.result.optimal.ts)
    );
    assert_eq!(pml.result.t_min, jobs[0].optimum_time().unwrap() as i64);
    assert!(
        pml.result.states_explored > native.result.states_explored,
        "full interleaving explores more states than the canonical schedule"
    );
    // the second drain of the same spec is served entirely from the cache
    let report2 = run_batch(&jobs, &opts, &mut cache).unwrap();
    assert!(report2.outcomes.iter().all(|o| o.cached));
    assert_eq!(report2.total_states(), 0);
}

#[test]
fn promela_cache_distinguishes_edited_sources() {
    // run a template job, then "edit" the model (explicit source with one
    // changed byte): the edited job must miss, not reuse the stale entry
    let mut job = TuningJob::new(ModelKind::Minimum, 16);
    job.engine = JobEngine::Promela;
    job.shards = 1;
    let mut cache = ResultCache::in_memory();
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    run_batch(std::slice::from_ref(&job), &opts, &mut cache).unwrap();
    assert_eq!(cache.len(), 1);

    // identical source text (explicit rather than template): hit
    let mut same = job.clone();
    same.source = Some(templates::minimum_pml(16, 4, 3));
    let r = run_batch(std::slice::from_ref(&same), &opts, &mut cache).unwrap();
    assert!(r.outcomes[0].cached, "byte-identical source must share the cache entry");

    // edited source: miss, fresh verification
    let mut edited = job.clone();
    edited.source = Some(format!("// tweaked\n{}", templates::minimum_pml(16, 4, 3)));
    let r = run_batch(std::slice::from_ref(&edited), &opts, &mut cache).unwrap();
    assert!(!r.outcomes[0].cached, "an edited model must never hit a stale entry");
    assert_eq!(cache.len(), 2);
}

#[test]
fn batch_shard_budgets_scale_with_sublattice_size() {
    let mut job = TuningJob::new(ModelKind::Minimum, 64);
    job.shards = 4;
    let mut opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    opts.check.max_states = 10_000_000; // finite, so the split is observable
    opts.check.time_budget = Some(std::time::Duration::from_secs(60));
    let mut cache = ResultCache::in_memory();
    let report = run_batch(std::slice::from_ref(&job), &opts, &mut cache).unwrap();
    let plan = &report.outcomes[0].plan;
    assert!(plan.len() >= 2, "expected a real split, got {:?}", plan.len());
    let mut sorted: Vec<_> = plan.iter().collect();
    sorted.sort_by_key(|p| p.weight);
    assert!(
        sorted.first().unwrap().weight < sorted.last().unwrap().weight,
        "the Minimum lattice is cost-skewed; shards must not weigh equal"
    );
    for w in sorted.windows(2) {
        assert!(
            w[1].check.max_states >= w[0].check.max_states,
            "larger sub-lattice must get a larger (or equal) state budget"
        );
        assert!(w[1].check.time_budget.unwrap() >= w[0].check.time_budget.unwrap());
    }
    // budgets sum to at most the job budget plus floor slack
    assert!(plan.iter().map(|p| p.check.max_states).sum::<u64>() <= opts.check.max_states * 2);
    // the rendered report surfaces the plan
    let rendered = report.render();
    assert!(rendered.contains("shard budgets"), "plan missing from report:\n{}", rendered);
    assert!(rendered.contains("weight "));
}

#[test]
fn adaptive_shard_count_kicks_in_when_unset() {
    // default_shards = 0 (adaptive): a size-64 Minimum job has enough
    // estimated weight to split, and the plan lands within the cap
    let job = TuningJob::new(ModelKind::Minimum, 64); // shards = 1 by construction
    let mut unset = job.clone();
    unset.shards = 0;
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    let mut cache = ResultCache::in_memory();
    let report = run_batch(std::slice::from_ref(&unset), &opts, &mut cache).unwrap();
    let shards = report.outcomes[0].shards;
    assert!(
        (1..=4).contains(&shards),
        "adaptive count must stay within [1, 2 x workers], got {}",
        shards
    );
    // an explicit shards= on the job still wins over the adaptive default
    let mut pinned = job.clone();
    pinned.shards = 2;
    let mut cache = ResultCache::in_memory();
    let report = run_batch(std::slice::from_ref(&pinned), &opts, &mut cache).unwrap();
    assert_eq!(report.outcomes[0].shards, 2);
}

#[test]
fn batch_survives_a_corrupt_cache_file() {
    // cache lifecycle edge: a truncated/corrupt cache JSON (e.g. a kill
    // mid-write outside the atomic-rename path) must not abort the batch
    // — it is quarantined as <file>.corrupt and rebuilt
    let path = temp_path("corrupt_batch");
    std::fs::write(&path, "{\"version\":1,\"entries\":[{\"de").unwrap();
    let jobs = vec![TuningJob::new(ModelKind::Minimum, 16)];
    let mut cache = ResultCache::open(&path).unwrap();
    let quarantine = std::path::PathBuf::from(format!("{}.corrupt", path.display()));
    assert_eq!(cache.quarantined(), Some(quarantine.as_path()));
    let report =
        run_batch(&jobs, &BatchOptions { workers: 2, ..BatchOptions::default() }, &mut cache)
            .unwrap();
    assert!(!report.outcomes[0].cached);
    assert_eq!(report.outcomes[0].result.t_min, jobs[0].optimum_time().unwrap() as i64);
    // the rebuilt cache file is valid again and serves the job
    let mut reopened = ResultCache::open(&path).unwrap();
    assert!(reopened.quarantined().is_none());
    let report2 = run_batch(&jobs, &BatchOptions::default(), &mut reopened).unwrap();
    assert!(report2.outcomes[0].cached);
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&quarantine).ok();
}

#[test]
fn external_pml_source_gets_proportional_shard_budgets() {
    // satellite acceptance: a skewed external .pml model (the Minimum
    // template read as an external source) must produce non-uniform
    // simulation-swept tuning costs, and plan_shards must scale the
    // budgets proportionally to the resulting sub-lattice weights
    use mcautotune::coordinator::{plan_shards, shard_weight};
    let mut job = TuningJob::new(ModelKind::Minimum, 16);
    job.engine = JobEngine::Promela;
    job.source = Some(templates::minimum_pml(16, 4, 3));
    job.shards = 3;
    let costs = job.tuning_costs().unwrap();
    assert!(
        costs.windows(2).any(|w| w[0].1 != w[1].1),
        "skewed model must not weigh uniform: {:?}",
        costs
    );
    let tunings: Vec<_> = costs.iter().map(|&(t, _)| t).collect();
    let mut base = CheckOptions::default();
    base.max_states = 1_000_000;
    base.time_budget = Some(std::time::Duration::from_secs(30));
    let plans = plan_shards(partition(&tunings, 3), &costs, &base);
    assert!(plans.len() >= 2);
    for p in &plans {
        assert_eq!(p.weight, shard_weight(&costs, &p.shard));
        assert_eq!(p.check.expected_states, p.weight, "presize follows the estimate");
    }
    let mut sorted = plans.clone();
    sorted.sort_by_key(|p| p.weight);
    assert!(
        sorted.first().unwrap().weight < sorted.last().unwrap().weight,
        "shard weights must differ on a skewed model"
    );
    for w in sorted.windows(2) {
        assert!(
            w[1].check.max_states >= w[0].check.max_states,
            "heavier sub-lattice must get a larger (or equal) state budget"
        );
        assert!(w[1].check.time_budget.unwrap() >= w[0].check.time_budget.unwrap());
    }
    // end to end: the batch planner accepts the same job and its report
    // carries the proportional plan
    let mut cache = ResultCache::in_memory();
    let opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    let report = run_batch(std::slice::from_ref(&job), &opts, &mut cache).unwrap();
    let outcome_plan = &report.outcomes[0].plan;
    assert_eq!(report.outcomes[0].shards as usize, outcome_plan.len());
    assert!(outcome_plan.iter().any(|p| p.weight != outcome_plan[0].weight));
}

#[test]
fn sharded_swarm_job_reaches_the_optimum() {
    // swarm method composes with sharding (partitioned-space workers on
    // top of diversified-seed workers)
    let mut job = TuningJob::new(ModelKind::Minimum, 64);
    job.method = Method::Swarm;
    job.shards = 2;
    let mut opts = BatchOptions { workers: 2, ..BatchOptions::default() };
    opts.swarm = SwarmConfig {
        workers: 2,
        time_budget: std::time::Duration::from_secs(5),
        ..SwarmConfig::default()
    };
    let mut cache = ResultCache::in_memory();
    let report = run_batch(&[job.clone()], &opts, &mut cache).unwrap();
    assert_eq!(report.outcomes[0].result.t_min, job.optimum_time().unwrap() as i64);
}
