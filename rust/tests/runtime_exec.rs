//! PJRT runtime integration — requires `make artifacts` (tests skip with a
//! notice when the artifacts are absent, e.g. in a docs-only checkout).

use mcautotune::opencl::{gen_data, run_sweep};
use mcautotune::runtime::Engine;

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(&dir).expect("engine"))
}

#[test]
fn engine_loads_manifest_and_platform() {
    let Some(eng) = engine() else { return };
    assert!(eng.manifest().entries.len() >= 3);
    assert_eq!(eng.platform().to_lowercase(), "cpu");
}

#[test]
fn small_kernel_result_matches_host_min_many_seeds() {
    let Some(mut eng) = engine() else { return };
    let n = eng.manifest().find("min_device_small").unwrap().size as usize;
    for seed in 0..16u64 {
        let data = gen_data(n, seed);
        let out = eng.run_min("min_device_small", &data).unwrap();
        assert_eq!(out.global_min, *data.iter().min().unwrap(), "seed {}", seed);
        // partials pointwise: workgroup g covers data[g*16..(g+1)*16]
        for (g, &p) in out.partials.iter().enumerate() {
            let lo = g * (n / out.partials.len());
            let hi = lo + n / out.partials.len();
            assert_eq!(p, *data[lo..hi].iter().min().unwrap());
        }
    }
}

#[test]
fn sweep_covers_all_twelve_configs_and_verifies() {
    let Some(mut eng) = engine() else { return };
    let rep = run_sweep(&mut eng, 1, 7).unwrap();
    assert_eq!(rep.rows.len(), 12);
    assert!(rep.rows.iter().all(|r| r.correct));
    // the sweep must vary WG at fixed global size and TS at fixed WG
    let wgs: std::collections::HashSet<u32> = rep.rows.iter().map(|r| r.wg).collect();
    let tss: std::collections::HashSet<u32> = rep.rows.iter().map(|r| r.ts).collect();
    assert!(wgs.len() >= 4);
    assert!(tss.len() >= 4);
}

#[test]
fn abstract_artifact_runs() {
    let Some(mut eng) = engine() else { return };
    let e = eng.manifest().find("abstract_small").unwrap().clone();
    let data: Vec<f32> = (0..e.size).map(|i| (i % 17) as f32 * 0.5).collect();
    let out = eng.run_abstract("abstract_small", &data).unwrap();
    assert_eq!(out.len(), e.wg as usize);
    assert!(out.iter().all(|v| v.is_finite()));
}
